"""The control-plane API (core/control.py; DESIGN.md §10).

* ``optimal_pass_fraction`` matches an independent brute-force sweep over
  candidate pass fractions (hypothesis property test);
* ``Telemetry`` is read-only and its views agree with
  ``InstancePool.load`` / ``total_in_flight`` / queue depth MID-RUN (an
  instrumented controller cross-checks at every decision point);
* the default ClassicMinosController path equals the policy-only engine
  bit-for-bit, and controller= / policy= are mutually exclusive;
* ReprobeController re-certifies drifted instances (retires slow ones,
  keeps fast ones) and never violates the solo-request invariant;
* QueueAwareAdmissionController defers under pressure, loses no items,
  and reduces replica churn on a pressured pipeline;
* PassFractionController adapts its fraction and lognormal threshold math
  is self-consistent;
* the deprecated ``ElysiumGate(online_controller=...)`` kwarg warns once.
"""
import math
import warnings

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # optional dev dependency (pyproject [dev] extra)
    from _hypothesis_stub import hypothesis, st
import numpy as np
import pytest

import repro.core.control as control
from repro.core.control import (
    AdmitContext,
    AdmitDecision,
    ClassicMinosController,
    ControllerBase,
    DelegatingController,
    ElysiumGate,
    PassFractionController,
    ProbeDecision,
    QueueAwareAdmissionController,
    ReprobeController,
    ReuseDecision,
    Telemetry,
    _norm_cdf,
    _norm_ppf,
    lognormal_pool_speedup,
)
from repro.core.cost import Pricing
from repro.core.elysium import OnlineElysiumController, optimal_pass_fraction
from repro.core.lifecycle import InstanceState
from repro.core.policy import AdaptiveMinosPolicy, MinosPolicy, Verdict
from repro.sim import (
    FaaSPlatform,
    FunctionSpec,
    Stage,
    VariationModel,
    WorkflowDAG,
    WorkflowEngine,
    run_workflow_batch,
)
from repro.sim.workload import run_closed_loop

PRICING = Pricing.gcf(256)


def _spec(**kw):
    base = dict(
        name="cp", prepare_ms=200.0, prepare_jitter=0.0, body_ms=900.0,
        body_jitter=0.0, benchmark_ms=150.0, benchmark_noise=0.0,
        cold_start_ms=50.0, cold_start_jitter=0.0,
        recycle_lifetime_ms=None, contention_rho=1.0,
    )
    base.update(kw)
    return FunctionSpec(**base)


# ---------------------------------------------------------------------------
# optimal_pass_fraction vs brute force (property test)
# ---------------------------------------------------------------------------


@hypothesis.given(
    benchmark_ms=st.floats(min_value=10.0, max_value=2000.0),
    body_ms=st.floats(min_value=10.0, max_value=50000.0),
    expected_reuses=st.floats(min_value=0.0, max_value=200.0),
    sigma=st.floats(min_value=0.01, max_value=0.8),
)
@hypothesis.settings(deadline=None, max_examples=60)
def test_optimal_pass_fraction_matches_brute_force(
        benchmark_ms, body_ms, expected_reuses, sigma):
    """The §II-A cost model, evaluated independently at every candidate
    fraction, must agree with optimal_pass_fraction's argmin."""
    fractions = tuple(float(f) for f in np.linspace(0.05, 0.95, 19))

    def speedup(f):
        return lognormal_pool_speedup(f, sigma)

    got = optimal_pass_fraction(
        benchmark_ms=benchmark_ms, body_ms=body_ms,
        expected_reuses=expected_reuses, speedup_at_fraction=speedup,
        fractions=fractions)

    costs = {
        f: benchmark_ms / f + (1.0 + expected_reuses) * body_ms / speedup(f)
        for f in fractions
    }
    brute = min(costs, key=costs.get)
    assert got == brute


def test_optimal_fraction_monotone_in_reuse():
    """More reuse amortizes selection waste ⇒ the optimal fraction can only
    get more selective (non-increasing) as expected reuses grow."""
    fs = [
        optimal_pass_fraction(
            benchmark_ms=300.0, body_ms=2000.0, expected_reuses=r,
            speedup_at_fraction=lambda f: lognormal_pool_speedup(f, 0.2))
        for r in (0.0, 2.0, 10.0, 50.0)
    ]
    assert all(b <= a for a, b in zip(fs, fs[1:]))
    assert fs[-1] < fs[0]  # and it actually moves on this range


# ---------------------------------------------------------------------------
# Lognormal helpers
# ---------------------------------------------------------------------------


@hypothesis.given(p=st.floats(min_value=1e-4, max_value=1.0 - 1e-4))
@hypothesis.settings(deadline=None, max_examples=60)
def test_norm_ppf_inverts_cdf(p):
    assert _norm_cdf(_norm_ppf(p)) == pytest.approx(p, abs=1e-9)


def test_lognormal_pool_speedup_against_monte_carlo():
    rng = np.random.RandomState(0)
    sigma = 0.3
    d = np.exp(rng.normal(0.0, sigma, size=200_000))
    for f in (0.2, 0.4, 0.7):
        q = np.quantile(d, f)
        emp = d.mean() / d[d <= q].mean()
        assert lognormal_pool_speedup(f, sigma) == pytest.approx(emp, rel=0.02)


def test_lognormal_pool_speedup_limits():
    assert lognormal_pool_speedup(0.4, 0.0) == 1.0
    assert lognormal_pool_speedup(0.999, 0.3) == pytest.approx(1.0, abs=0.01)
    assert lognormal_pool_speedup(0.2, 0.4) > lognormal_pool_speedup(0.2, 0.1)


# ---------------------------------------------------------------------------
# Telemetry: read-only, and consistent with the pool mid-run
# ---------------------------------------------------------------------------


def test_telemetry_is_read_only():
    plat = FaaSPlatform(_spec(), VariationModel(sigma=0.1),
                        MinosPolicy(elysium_threshold=1e9), PRICING, seed=0)
    t = plat.telemetry
    with pytest.raises(AttributeError):
        t.now_ms = 5.0
    with pytest.raises(AttributeError):
        t.queue_depth = 3
    with pytest.raises(AttributeError):
        del t.now_ms
    with pytest.raises(AttributeError):
        t.anything_else = object()


class _ConsistencyChecker(DelegatingController):
    """Cross-checks, at every decision point, that the Telemetry view
    agrees with the engine's pool/queue ground truth at that instant."""

    def __init__(self, inner):
        super().__init__(inner)
        self.engine = None
        self.checks = 0

    def _check(self, t: Telemetry):
        eng = self.engine
        assert t.total_in_flight == eng.pool.total_in_flight
        assert t.pool_available == len(eng.pool)
        assert t.pool_instances == eng.pool.n_instances
        assert t.mean_load == eng.pool.mean_load()
        assert t.queue_depth == len(eng.queue)
        assert t.now_ms == eng.loop.now
        assert t.n_probes == eng.probe_stats.count
        if eng.reuse_stats.count:
            assert 0.0 <= t.reuse_rate <= 1.0
        self.checks += 1

    def on_cold_start(self, ctx):
        self._check(ctx.telemetry)
        return self.inner.on_cold_start(ctx)

    def on_probe(self, ctx):
        self._check(ctx.telemetry)
        assert ctx.telemetry.instance_load(ctx.instance) >= 1
        return self.inner.on_probe(ctx)

    def on_reuse(self, ctx):
        self._check(ctx.telemetry)
        # reuse decisions are only offered for solo requests
        assert ctx.telemetry.instance_load(ctx.instance) == 1
        return self.inner.on_reuse(ctx)

    def on_release(self, ctx):
        self._check(ctx.telemetry)
        return self.inner.on_release(ctx)


def test_telemetry_consistent_with_pool_mid_run():
    checker = _ConsistencyChecker(
        ClassicMinosController(AdaptiveMinosPolicy(0.4, max_retries=4)))
    plat = FaaSPlatform(
        _spec(benchmark_noise=0.05, recycle_lifetime_ms=20_000.0,
              contention_rho=0.97),
        VariationModel(sigma=0.2), None, PRICING, seed=5, controller=checker)
    checker.engine = plat
    res = run_closed_loop(plat, n_vus=4, duration_ms=60_000.0)
    assert len(res) > 50
    assert checker.checks > 200  # every decision point cross-checked


# ---------------------------------------------------------------------------
# Engine construction and classic parity
# ---------------------------------------------------------------------------


def test_explicit_classic_controller_matches_policy_path():
    """Passing ClassicMinosController(policy) must be bit-identical to
    passing the policy (the engine builds the same controller itself)."""
    spec = _spec(benchmark_noise=0.05, recycle_lifetime_ms=20_000.0,
                 contention_rho=0.96, prepare_jitter=0.1, body_jitter=0.02,
                 cold_start_jitter=0.2)
    vm = VariationModel(sigma=0.2)

    def digest(**kw):
        plat = FaaSPlatform(spec, vm, kw.get("policy"), PRICING, seed=11,
                            controller=kw.get("controller"))
        res = run_closed_loop(plat, n_vus=5, duration_ms=90_000.0)
        return ([round(r.latency_ms, 9) for r in res],
                plat.instances_started, plat.instances_terminated,
                round(plat.cost.total, 12))

    a = digest(policy=MinosPolicy(elysium_threshold=170.0, max_retries=4))
    b = digest(controller=ClassicMinosController(
        MinosPolicy(elysium_threshold=170.0, max_retries=4)))
    assert a == b


def test_engine_rejects_policy_and_controller_together():
    with pytest.raises(TypeError, match="not both"):
        FaaSPlatform(_spec(), VariationModel(sigma=0.1),
                     MinosPolicy(elysium_threshold=1.0), PRICING,
                     controller=ControllerBase())
    with pytest.raises(TypeError, match="policy"):
        FaaSPlatform(_spec(), VariationModel(sigma=0.1), None, PRICING)


def test_workflow_engine_rejects_both_factories():
    dag = WorkflowDAG([Stage(_spec())])
    with pytest.raises(ValueError, match="exactly one"):
        WorkflowEngine(dag, VariationModel(sigma=0.1),
                       lambda s: MinosPolicy(elysium_threshold=1.0),
                       pricing=PRICING,
                       controller_factory=lambda s: ControllerBase())
    with pytest.raises(ValueError, match="exactly one"):
        WorkflowEngine(dag, VariationModel(sigma=0.1), pricing=PRICING)


# ---------------------------------------------------------------------------
# ReprobeController
# ---------------------------------------------------------------------------


def test_reprobe_retires_drifted_instance_and_keeps_fast_one():
    """Deterministic drift: an instance certified fast whose speed then
    collapses must be re-probed at the trigger and retired; without drift
    the re-probe passes and the instance keeps serving."""

    class Collapse:
        """Variation stub: first instance fast; replacements nominal."""

        def __init__(self):
            self.n = 0

        def sample_speed(self, rng, t_ms=0.0):
            self.n += 1
            return 2.0 if self.n == 1 else 1.0

    spec = _spec()
    vm = VariationModel(sigma=0.0)
    ctrl = ReprobeController(
        ClassicMinosController(MinosPolicy(elysium_threshold=200.0,
                                           max_retries=3)),
        max_uses_since_probe=4)
    plat = FaaSPlatform(spec, vm, None, PRICING, seed=0, controller=ctrl)
    # monkey-wire deterministic speeds + a mid-run collapse
    collapse = Collapse()
    plat.backend.sample_speed = collapse.sample_speed
    plat.backend.reuse_drift = lambda inst, rng, t: None

    done = []
    for i in range(4):  # cold + 3 warm serves → next reuse triggers re-probe
        plat.submit({"i": i}, done.append)
        plat.loop.run_all()
    assert plat.reprobes == 0
    inst = plat.pool.available[0]
    assert inst.serves_since_probe == 4
    # collapse the certified speed; the trigger re-probe must catch it
    inst.speed_factor = 0.2  # probe now takes 150/0.2 = 750ms > 200ms bar
    plat.submit({"i": 99}, done.append)
    plat.loop.run_all()
    assert plat.reprobes == 1
    assert plat.instances_retired == 1
    assert inst.state is InstanceState.TERMINATED
    assert len(done) == 5                      # the request still completed
    assert done[-1].retries == 1               # ...after one migration
    assert done[-1].instance_speed == 1.0      # ...on a fresh instance
    # the fresh instance passed a cold probe; serving continues
    assert plat.pool.n_instances == 1


def test_reprobe_passes_and_refreshes_certification_age():
    ctrl = ReprobeController(
        ClassicMinosController(MinosPolicy(elysium_threshold=200.0,
                                           max_retries=3)),
        max_uses_since_probe=2)
    plat = FaaSPlatform(_spec(), VariationModel(sigma=0.0), None, PRICING,
                        seed=0, controller=ctrl)
    done = []
    for i in range(6):
        plat.submit({"i": i}, done.append)
        plat.loop.run_all()
    # serves 1,2 → reprobe on 3rd reuse; passes; counter resets and repeats
    assert plat.reprobes == 2
    assert plat.instances_retired == 0
    assert plat.instances_started == 1
    assert len(done) == 6
    inst = plat.pool.available[0]
    assert inst.last_probe_ms is not None


def test_reprobe_requires_a_trigger():
    inner = ClassicMinosController(MinosPolicy(elysium_threshold=1.0))
    with pytest.raises(ValueError, match="max_uses_since_probe"):
        ReprobeController(inner)
    assert ReprobeController.half_life_uses(0.95) == 14
    with pytest.raises(ValueError):
        ReprobeController.half_life_uses(1.0)


class _NoReprobeProxy:
    """Backend proxy that hides the optional ``reprobe`` hook."""

    def __init__(self, inner):
        self._inner = inner

    def __getattr__(self, name):
        if name == "reprobe":
            raise AttributeError(name)
        return getattr(self._inner, name)


def test_backend_without_reprobe_degrades_to_keep():
    """A backend lacking the optional reprobe hook must serve normally
    (REPROBE quietly becomes KEEP) — third-party backends keep working."""
    ctrl = ReprobeController(
        ClassicMinosController(MinosPolicy(elysium_threshold=200.0)),
        max_uses_since_probe=1)
    plat = FaaSPlatform(_spec(), VariationModel(sigma=0.0), None, PRICING,
                        seed=0, controller=ctrl)
    plat.backend = _NoReprobeProxy(plat.backend)
    done = []
    for i in range(3):
        plat.submit({"i": i}, done.append)
        plat.loop.run_all()
    assert len(done) == 3
    assert plat.reprobes == 0


# ---------------------------------------------------------------------------
# QueueAwareAdmissionController
# ---------------------------------------------------------------------------


def test_queue_aware_admission_defers_and_loses_nothing():
    """Under a burst far beyond capacity, the dynamic bound defers items at
    admission, every item still completes, and fewer instances are
    started than under static (unbounded) admission."""
    spec = _spec(body_ms=400.0, recycle_lifetime_ms=None)
    vm = VariationModel(sigma=0.0)

    def run(arm):
        def factory(stage):
            inner = ClassicMinosController(
                MinosPolicy(elysium_threshold=1e9, max_retries=3))
            if arm == "queue-aware":
                return QueueAwareAdmissionController(inner, headroom=1.0,
                                                     min_slots=2)
            return inner
        dag = WorkflowDAG([Stage(spec)], name=arm)
        eng = WorkflowEngine(dag, vm, controller_factory=factory,
                             pricing=PRICING, seed=0)
        res = run_workflow_batch(eng, n_items=30, inter_arrival_ms=0.0)
        return eng, res

    eng_s, res_s = run("static")
    eng_q, res_q = run("queue-aware")
    assert res_s.n_items == res_q.n_items == 30
    assert eng_q.admission_queue_depth("cp") == 0   # fully drained
    ctrl = eng_q.platforms["cp"].controller
    assert ctrl.deferred > 0
    assert eng_q.instances_started < eng_s.instances_started


def test_queue_aware_respects_static_bound_first():
    inner = ClassicMinosController(MinosPolicy(elysium_threshold=1.0))
    ctrl = QueueAwareAdmissionController(inner, headroom=100.0)

    class _T:
        pass

    t = _T()
    t.knobs = type("K", (), {"max_pool": None, "per_instance_concurrency": 1})()
    t.pool_instances = 1
    t.total_in_flight = 0
    t.queue_depth = 0
    ctx = AdmitContext(telemetry=t, in_flight=5, bound=5,
                       admission_queue_depth=0)
    assert ctrl.on_admit(ctx) is AdmitDecision.DEFER  # static bound wins
    ctx2 = AdmitContext(telemetry=t, in_flight=4, bound=5,
                        admission_queue_depth=0)
    assert ctrl.on_admit(ctx2) is AdmitDecision.ADMIT


# ---------------------------------------------------------------------------
# PassFractionController
# ---------------------------------------------------------------------------


def test_pass_fraction_controller_adapts_and_gates():
    ctrl = PassFractionController(0.4, update_every=4, warmup_reports=5)
    plat = FaaSPlatform(
        _spec(benchmark_noise=0.05, recycle_lifetime_ms=10_000.0,
              contention_rho=0.97),
        VariationModel(sigma=0.2), None, PRICING, seed=7, controller=ctrl)
    res = run_closed_loop(plat, n_vus=6, duration_ms=5 * 60_000.0)
    assert len(res) > 100
    assert ctrl.threshold is not None
    assert len(ctrl.fraction_history) > 0
    assert 0.05 <= ctrl.pass_fraction <= 0.95
    assert plat.instances_terminated > 0        # the gate actually engaged
    # high reuse on this workload pushes the fraction below the 0.4 start
    assert ctrl.pass_fraction < 0.4
    # telemetry estimates the controller consumed are live and sane
    t = plat.telemetry
    assert t.n_probes == len(ctrl.observations)
    assert 0.0 < t.reuse_rate < 1.0
    assert math.isfinite(t.probe_log_std) and t.probe_log_std > 0.0


def test_pass_fraction_controller_warmup_passes_everything():
    ctrl = PassFractionController(0.4, warmup_reports=5)
    plat = FaaSPlatform(_spec(), VariationModel(sigma=0.3), None, PRICING,
                        seed=1, controller=ctrl)
    done = []
    for i in range(3):  # fewer than warmup_reports cold starts
        plat.submit({"i": i}, done.append)
        plat.loop.run_all()
    assert plat.instances_terminated == 0
    assert ctrl.threshold is None


def test_pass_fraction_controller_validation():
    with pytest.raises(ValueError):
        PassFractionController(0.0)
    with pytest.raises(ValueError):
        PassFractionController(0.4, update_every=0)


# ---------------------------------------------------------------------------
# Deprecation + decision accounting
# ---------------------------------------------------------------------------


def test_elysium_gate_online_controller_kwarg_warns_once():
    control._gate_kwarg_warned = False  # reset the once-guard
    ctl = OnlineElysiumController(initial_threshold=100.0)
    with pytest.warns(DeprecationWarning, match="ClassicMinosController"):
        ElysiumGate(MinosPolicy(elysium_threshold=1.0), online_controller=ctl)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second construction must NOT warn
        ElysiumGate(MinosPolicy(elysium_threshold=1.0), online_controller=ctl)
    # the engine-internal path (ClassicMinosController) never warns
    control._gate_kwarg_warned = False
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ClassicMinosController(MinosPolicy(elysium_threshold=1.0),
                               online_controller=ctl)
    control._gate_kwarg_warned = True


def test_decision_summary_names_every_handler():
    """Wrapper stacks attribute each decision point to the controller that
    actually answers it."""
    inner = ClassicMinosController(AdaptiveMinosPolicy(0.4, max_retries=4))
    ctrl = QueueAwareAdmissionController(
        ReprobeController(inner, max_uses_since_probe=2), headroom=1.0)
    assert ctrl.handler_name("on_admit") == "queue-admission"
    assert ctrl.handler_name("on_reuse") == "reprobe"
    assert ctrl.handler_name("on_probe").startswith("classic")
    plat = FaaSPlatform(_spec(benchmark_noise=0.05),
                        VariationModel(sigma=0.2), None, PRICING, seed=3,
                        controller=ctrl)
    done = []
    for i in range(8):
        plat.submit({"i": i}, done.append)
        plat.loop.run_all()
    summary = plat.controller.decision_summary()
    assert "on_cold_start=classic" in summary
    assert "on_reuse=reprobe" in summary
    assert "on_release=classic" in summary
