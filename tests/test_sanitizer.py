"""Runtime substrate sanitizer (repro.analysis.sanitizer; DESIGN.md §13).

* armed pools/engines pass untouched on clean workloads (the wrappers
  change nothing but the checking);
* each injected corruption class — counter drift, heap staleness, live-id
  desync, ledger imbalance, non-finite outputs — raises SanitizerError;
* the retire-under-load stress: the lazily-invalidated spread heap and
  the O(1) total_in_flight counter must agree with their O(n)
  recomputations after every retire() in a randomized take/release/retire
  storm (the full-check-after-retire path);
* the env gate: REPRO_SANITIZE unset/0 attaches nothing.
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.analysis.sanitizer import (
    SanitizerError,
    attach_engine,
    attach_pool,
    check_engine_conservation,
    check_finite,
    check_open_loop,
    check_pool,
    check_telemetry_readonly,
)
from repro.core.lifecycle import FunctionInstance, InstanceState
from repro.core.policy import MinosPolicy
from repro.core.substrate import InstancePool
from repro.sim import (
    FaaSPlatform,
    FunctionSpec,
    PlatformProfile,
    VariationModel,
)
from repro.sim.arrivals import PoissonProcess, run_open_loop

SPEC = FunctionSpec(name="sanitize", prepare_ms=80.0, body_ms=150.0,
                    benchmark_ms=40.0, cold_start_ms=60.0)
VM = VariationModel(sigma=0.2)
PROFILE = PlatformProfile.gcf_gen1()


def _warm(pool, speed=1.0, now=0.0):
    inst = FunctionInstance(speed_factor=speed, created_at_ms=now,
                            idle_timeout_ms=1e9)
    inst.state = InstanceState.WARM
    inst.last_used_ms = now
    pool.add_warm(inst)
    return inst


def _policy():
    return MinosPolicy(elysium_threshold=float("inf"), enabled=False)


def _platform(*, seed=0, max_instances=3, queue_capacity=None):
    knobs = dataclasses.replace(PROFILE.knobs(), max_instances=max_instances,
                                queue_capacity=queue_capacity)
    return FaaSPlatform(SPEC, VM, _policy(), seed=seed, profile=PROFILE,
                        knobs=knobs)


# ---------------------------------------------------------------------------
# Env gate
# ---------------------------------------------------------------------------


def test_enabled_gates_on_env(monkeypatch):
    monkeypatch.delenv(sanitizer.ENV_VAR, raising=False)
    assert not sanitizer.enabled()
    monkeypatch.setenv(sanitizer.ENV_VAR, "0")
    assert not sanitizer.enabled()
    monkeypatch.setenv(sanitizer.ENV_VAR, "1")
    assert sanitizer.enabled()


def test_engine_not_armed_by_default(monkeypatch):
    monkeypatch.delenv(sanitizer.ENV_VAR, raising=False)
    engine = _platform()
    assert not getattr(engine, "_sanitizer_armed", False)


def test_engine_armed_under_env(monkeypatch):
    monkeypatch.setenv(sanitizer.ENV_VAR, "1")
    engine = _platform()
    assert engine._sanitizer_armed
    assert engine.pool._sanitizer_armed


# ---------------------------------------------------------------------------
# Pool checks
# ---------------------------------------------------------------------------


def test_clean_pool_lifecycle_passes():
    pool = InstancePool(order="spread", concurrency=2)
    attach_pool(pool)
    insts = [_warm(pool) for _ in range(4)]
    taken = [pool.take(0.0) for _ in range(5)]
    for inst in taken:
        assert inst is not None
        pool.release(inst, 1.0)
    pool.retire(insts[0])
    check_pool(pool)
    assert pool.total_in_flight == 0


def test_corrupted_in_flight_counter_raises():
    pool = InstancePool(order="lifo")
    _warm(pool)
    pool.take(0.0)
    pool._in_flight += 1  # inject counter drift
    with pytest.raises(SanitizerError, match="_in_flight diverged"):
        check_pool(pool)


def test_corrupted_live_ids_raises():
    pool = InstancePool(order="lifo")
    _warm(pool)
    pool._live_ids.add(999_999)
    with pytest.raises(SanitizerError, match="_live_ids"):
        check_pool(pool)


def test_duplicate_available_entry_raises():
    pool = InstancePool(order="lifo")
    inst = _warm(pool)
    pool.available.append(inst)  # bypass the API (the forbidden mutation)
    with pytest.raises(SanitizerError, match="duplicate|_avail_seq"):
        check_pool(pool)


def test_stale_spread_heap_raises():
    pool = InstancePool(order="spread", concurrency=4)
    a, b = _warm(pool), _warm(pool)
    pool.take(0.0)  # loads a (FIFO tie-break); b (load 0) is now the argmin
    # corrupt the latest-push marker for b: every heap entry naming the
    # true argmin goes stale, so the heap would serve a instead of b
    pool._spread_latest[b.instance_id] = -1
    with pytest.raises(SanitizerError, match="spread heap"):
        check_pool(pool)


def test_armed_pool_catches_corruption_at_retire():
    pool = InstancePool(order="spread", concurrency=2)
    attach_pool(pool)
    insts = [_warm(pool) for _ in range(3)]
    pool._in_flight = 7  # drift injected between mutator calls
    with pytest.raises(SanitizerError, match="_in_flight"):
        pool.retire(insts[-1])


def test_retire_under_load_stress():
    """Satellite check: the lazily-invalidated spread heap and the O(1)
    total_in_flight stay equal to their O(n) recomputes after retire()
    under a randomized take/release/retire storm. attach_pool runs the
    full structural check after every retire."""
    rng = np.random.RandomState(42)
    pool = InstancePool(order="spread", concurrency=3,
                        recycle_lifetime_ms=50_000.0,
                        rng=np.random.RandomState(7))
    attach_pool(pool)
    held = []
    for step in range(600):
        op = rng.rand()
        if op < 0.45:
            inst = pool.take(float(step))
            if inst is not None:
                held.append(inst)
            elif len(pool._live_ids) < 12:
                held.append(_warm(pool, now=float(step)))
                pool.take(float(step))
        elif op < 0.85 and held:
            pool.release(held.pop(rng.randint(len(held))), float(step))
        elif held:
            # retire the engine way: only at load 1 (pool invariant)
            solo = [i for i in held if pool.load(i) == 1]
            if solo:
                victim = solo[rng.randint(len(solo))]
                held.remove(victim)
                pool.retire(victim)  # full check fires here
        assert pool.total_in_flight == sum(pool._active.values())
    for inst in held:
        pool.release(inst, 1e6)
    check_pool(pool)
    assert pool.total_in_flight == 0


# ---------------------------------------------------------------------------
# Engine ledger + telemetry
# ---------------------------------------------------------------------------


def test_armed_engine_clean_run(monkeypatch):
    monkeypatch.setenv(sanitizer.ENV_VAR, "1")
    engine = _platform(seed=3)
    done = []
    for i in range(12):
        engine.submit({"user": f"u{i}"}, done.append)
    engine.loop.run_all()
    assert len(done) == 12
    check_engine_conservation(engine, where="test")
    check_pool(engine.pool, where="test")


def test_conservation_violation_raises(monkeypatch):
    monkeypatch.setenv(sanitizer.ENV_VAR, "1")
    engine = _platform(seed=4)
    engine.submit({"user": "u"}, lambda res: None)
    engine.requests_arrived += 1  # forge an arrival with no disposition
    with pytest.raises(SanitizerError, match="conservation"):
        engine.loop.run_all()


def test_telemetry_readonly_holds_and_detects():
    engine = _platform(seed=5)
    check_telemetry_readonly(engine.telemetry)  # real view: must pass

    class Writable:
        pass

    with pytest.raises(SanitizerError, match="Telemetry accepted"):
        check_telemetry_readonly(Writable())


def test_open_loop_under_sanitizer(monkeypatch):
    monkeypatch.setenv(sanitizer.ENV_VAR, "1")
    engine = _platform(seed=6, queue_capacity=4)
    run = run_open_loop(engine, PoissonProcess(20.0),
                        rng=np.random.RandomState(0), duration_ms=20_000.0,
                        drain=True)
    assert run.n_arrived == (len(run.results) + run.n_dropped
                             + run.n_pending_at_end)


def test_check_open_loop_mismatch_raises():
    with pytest.raises(SanitizerError, match="open-loop conservation"):
        check_open_loop(n_arrived=10, n_completed=5, n_dropped=2,
                        n_pending_at_end=1)


# ---------------------------------------------------------------------------
# Output guards
# ---------------------------------------------------------------------------


def test_check_finite_passes_and_raises():
    check_finite({"ok": np.ones(3), "ints": np.arange(3)})
    with pytest.raises(SanitizerError, match="non-finite"):
        check_finite({"bad": np.array([1.0, np.nan])})
    with pytest.raises(SanitizerError, match="non-finite"):
        check_finite({"bad": np.array([np.inf])})


def test_vectorized_summary_guard(monkeypatch):
    monkeypatch.setenv(sanitizer.ENV_VAR, "1")
    from repro.sim.vectorized import arm_from_spec, simulate_arms, stack_arms

    arm = arm_from_spec(SPEC, VM, profile=PROFILE, gate="off")
    res = simulate_arms(stack_arms([arm]), seeds=[0], n_steps=64,
                        pool_size=4)
    assert np.isfinite(res.summary["mean_latency_ms"]).all()


# ---------------------------------------------------------------------------
# Fleet conservation ledger (repro.fleet; DESIGN.md §14)
# ---------------------------------------------------------------------------

_FLEET_OK = dict(
    n_arrived=10, n_completed=7, n_dropped=1, n_pending=2,
    n_hedges=3, n_hedge_dropped=1, n_hedge_cancelled=2,
    per_fleet_arrived=(8, 5), per_fleet_completed=(6, 3),
    per_fleet_dropped=(1, 1), per_fleet_parked=(1, 1))


def test_fleet_conservation_accepts_consistent_ledger():
    sanitizer.check_fleet_conservation(**_FLEET_OK)


@pytest.mark.parametrize("mutation,match", [
    ({"n_pending": 3}, "logical conservation"),
    # one extra engine arrival nobody logged: the double-dispatch shape
    ({"per_fleet_arrived": (9, 5), "per_fleet_parked": (2, 1)},
     "double dispatch"),
    ({"n_hedge_cancelled": 1}, "completion ledger"),
    ({"n_hedge_dropped": 0}, "drop ledger"),
    ({"per_fleet_parked": (0, 1)}, "per-fleet conservation"),
])
def test_fleet_conservation_raises_on_each_imbalance(mutation, match):
    bad = dict(_FLEET_OK)
    bad.update(mutation)
    with pytest.raises(SanitizerError, match=match):
        sanitizer.check_fleet_conservation(**bad)


def test_fleet_run_checks_ledger_when_armed(monkeypatch):
    monkeypatch.setenv(sanitizer.ENV_VAR, "1")
    from repro.core.policy import MinosPolicy as _MP
    from repro.fleet import (FleetRouter, FleetSpec, RandomRoutingPolicy,
                             run_fleet_open_loop)
    from repro.sim.arrivals import PoissonProcess

    fleets = [
        FleetSpec(name=f"s{i}", spec=SPEC, variation=VM, profile=PROFILE,
                  knobs=dataclasses.replace(PROFILE.knobs(),
                                            max_instances=2),
                  policy=_MP(elysium_threshold=float("inf"),
                             enabled=False))
        for i in range(2)
    ]
    router = FleetRouter(fleets, RandomRoutingPolicy(), seed=0,
                         hedge_after_ms=800.0)
    run = run_fleet_open_loop(router, PoissonProcess(2.0),
                              rng=np.random.RandomState(4),
                              duration_ms=15_000.0)
    assert run.n_arrived == run.n_completed + run.n_dropped \
        + run.n_pending_at_end
