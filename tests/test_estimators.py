"""Online estimators: exactness (Welford), convergence (P²), and the
Python/JAX implementations agreeing — including hypothesis property tests."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # optional dev dependency (pyproject [dev] extra)
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimators import (
    P2Quantile,
    Welford,
    p2_init,
    p2_update,
    p2_value,
    welford_init,
    welford_merge,
    welford_std,
    welford_update,
)

finite_floats = st.floats(min_value=-1e6, max_value=1e6, allow_nan=False)


@hypothesis.given(st.lists(finite_floats, min_size=2, max_size=200))
@hypothesis.settings(deadline=None, max_examples=50)
def test_welford_matches_numpy(xs):
    w = Welford()
    w.update_many(xs)
    assert w.count == len(xs)
    np.testing.assert_allclose(w.mean, np.mean(xs), rtol=1e-9, atol=1e-6)
    np.testing.assert_allclose(w.std, np.std(xs, ddof=1), rtol=1e-7, atol=1e-5)


@hypothesis.given(
    st.lists(finite_floats, min_size=1, max_size=80),
    st.lists(finite_floats, min_size=1, max_size=80),
)
@hypothesis.settings(deadline=None, max_examples=30)
def test_welford_merge_equals_concat(a, b):
    wa, wb, wc = Welford(), Welford(), Welford()
    wa.update_many(a)
    wb.update_many(b)
    wc.update_many(a + b)
    merged = wa.merge(wb)
    np.testing.assert_allclose(merged.mean, wc.mean, rtol=1e-8, atol=1e-6)
    np.testing.assert_allclose(merged.m2, wc.m2, rtol=1e-6, atol=1e-3)


def test_welford_jax_matches_python():
    xs = np.random.RandomState(0).lognormal(0, 0.4, 1000).astype(np.float32)
    w = Welford()
    w.update_many(xs)
    st_ = welford_init()
    st_ = jax.lax.scan(lambda s, x: (welford_update(s, x), None), st_, jnp.asarray(xs))[0]
    np.testing.assert_allclose(float(st_.mean), w.mean, rtol=1e-4)
    np.testing.assert_allclose(float(welford_std(st_)), w.std, rtol=1e-3)


def test_welford_merge_jax():
    xs = np.random.RandomState(1).normal(5, 2, 400).astype(np.float32)
    sa = welford_init()
    sb = welford_init()
    for x in xs[:150]:
        sa = welford_update(sa, jnp.float32(x))
    for x in xs[150:]:
        sb = welford_update(sb, jnp.float32(x))
    m = welford_merge(sa, sb)
    np.testing.assert_allclose(float(m.mean), xs.mean(), rtol=1e-4)


@pytest.mark.parametrize("p", [0.25, 0.5, 0.6, 0.9])
def test_p2_converges(p):
    rs = np.random.RandomState(42)
    xs = rs.lognormal(0.0, 0.5, 8000)
    est = P2Quantile(p)
    est.update_many(xs)
    true = np.quantile(xs, p)
    assert abs(est.value - true) / true < 0.03, (est.value, true)


def test_p2_small_sample_exact():
    est = P2Quantile(0.5)
    for x in [5.0, 1.0, 3.0]:
        est.update(x)
    assert est.value == 3.0  # exact median of 3 samples


def test_p2_jax_matches_python():
    rs = np.random.RandomState(7)
    xs = rs.gamma(2.0, 1.5, 5000).astype(np.float32)
    py = P2Quantile(0.6)
    py.update_many(xs)
    st_ = p2_init(0.6)
    st_ = jax.lax.scan(lambda s, x: (p2_update(s, x), None), st_, jnp.asarray(xs))[0]
    true = np.quantile(xs, 0.6)
    assert abs(float(p2_value(st_)) - true) / true < 0.03
    assert abs(float(p2_value(st_)) - py.value) / py.value < 0.02


@hypothesis.given(st.lists(st.floats(min_value=0.01, max_value=1e4,
                                     allow_nan=False), min_size=5, max_size=300))
@hypothesis.settings(deadline=None, max_examples=30)
def test_p2_value_within_observed_range(xs):
    """P² estimate must always lie inside [min, max] of the data."""
    est = P2Quantile(0.6)
    est.update_many(xs)
    assert min(xs) - 1e-9 <= est.value <= max(xs) + 1e-9


def test_p2_rejects_bad_quantile():
    with pytest.raises(ValueError):
        P2Quantile(0.0)
    with pytest.raises(ValueError):
        P2Quantile(1.0)


def test_welford_update_masked_equals_filtered_updates():
    """welford_update_masked(state, x, mask) must equal applying the plain
    update to exactly the mask-selected observations (identity when the
    mask is false) — the contract the vectorized simulator's fused probe
    accounting relies on."""
    from repro.core.estimators import welford_update_masked

    rng = np.random.RandomState(3)
    xs = rng.lognormal(0.0, 0.5, 200).astype(np.float32)
    mask = rng.rand(200) < 0.4
    st_m = welford_init()
    st_ref = welford_init()
    for x, m in zip(xs, mask):
        st_m = welford_update_masked(st_m, jnp.float32(x), jnp.asarray(bool(m)))
        if m:
            st_ref = welford_update(st_ref, jnp.float32(x))
    assert int(st_m.count) == int(mask.sum()) == int(st_ref.count)
    np.testing.assert_allclose(float(st_m.mean), float(st_ref.mean), rtol=1e-6)
    np.testing.assert_allclose(float(welford_std(st_m)),
                               float(welford_std(st_ref)), rtol=1e-5)
    # all-false mask: exact identity, including the empty state
    st0 = welford_update_masked(welford_init(), jnp.float32(5.0),
                                jnp.asarray(False))
    assert float(st0.count) == 0.0 and float(st0.mean) == 0.0 \
        and float(st0.m2) == 0.0
