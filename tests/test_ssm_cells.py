"""Chunked recurrent cells vs naive per-step recurrences (the oracles)."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # optional dev dependency (pyproject [dev] extra)
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (
    mlstm_chunked,
    mlstm_step,
    slstm_scan,
    ssd_chunked,
    ssd_step,
)


def _ssd_naive(x, dt, A, Bm, Cm, D):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    y = np.zeros((B, S, H, P), np.float32)
    h = np.zeros((B, H, N, P), np.float32)
    for t in range(S):
        a = np.exp(dt[:, t] * A[None, :])
        h = h * a[..., None, None] + np.einsum(
            "bn,bhp->bhnp", Bm[:, t], x[:, t] * dt[:, t][..., None])
        y[:, t] = np.einsum("bn,bhnp->bhp", Cm[:, t], h) + x[:, t] * D[None, :, None]
    return y, h


@pytest.mark.parametrize("S,chunk", [(64, 16), (48, 16), (33, 8), (16, 64)])
def test_ssd_chunked_matches_naive(S, chunk):
    rs = np.random.RandomState(0)
    B, H, P, N = 2, 3, 8, 5
    x = rs.randn(B, S, H, P).astype(np.float32)
    dt = np.abs(rs.randn(B, S, H)).astype(np.float32) * 0.5
    A = -np.abs(rs.randn(H)).astype(np.float32)
    Bm = rs.randn(B, S, N).astype(np.float32)
    Cm = rs.randn(B, S, N).astype(np.float32)
    D = rs.randn(H).astype(np.float32)
    want_y, want_h = _ssd_naive(x, dt, A, Bm, Cm, D)
    y, h = ssd_chunked(*map(jnp.asarray, (x, dt, A, Bm, Cm, D)), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), want_y, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h), want_h, rtol=3e-4, atol=3e-4)


def test_ssd_step_chain_matches_chunked():
    rs = np.random.RandomState(1)
    B, S, H, P, N = 1, 12, 2, 4, 3
    x = rs.randn(B, S, H, P).astype(np.float32)
    dt = np.abs(rs.randn(B, S, H)).astype(np.float32)
    A = -np.abs(rs.randn(H)).astype(np.float32)
    Bm = rs.randn(B, S, N).astype(np.float32)
    Cm = rs.randn(B, S, N).astype(np.float32)
    D = np.zeros(H, np.float32)
    y_c, h_c = ssd_chunked(*map(jnp.asarray, (x, dt, A, Bm, Cm, D)), chunk=4)
    h = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        y, h = ssd_step(jnp.asarray(x[:, t]), jnp.asarray(dt[:, t]), jnp.asarray(A),
                        jnp.asarray(Bm[:, t]), jnp.asarray(Cm[:, t]), jnp.asarray(D), h)
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_c), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_c), rtol=2e-4, atol=2e-4)


def _mlstm_naive(q, k, v, ig, fg):
    B, S, H, P = q.shape
    scale = P**-0.5
    C = np.zeros((B, H, P, P)); n = np.zeros((B, H, P)); m = np.full((B, H), -1e30)
    out = np.zeros((B, S, H, P), np.float32)
    for t in range(S):
        logf = -np.logaddexp(0, -fg[:, t])
        m_new = np.maximum(logf + m, ig[:, t])
        f_s = np.exp(logf + m - m_new); i_s = np.exp(ig[:, t] - m_new)
        C = C * f_s[..., None, None] + i_s[..., None, None] * k[:, t][..., :, None] * v[:, t][..., None, :]
        n = n * f_s[..., None] + i_s[..., None] * k[:, t]
        qf = q[:, t] * scale
        num = np.einsum("bhp,bhpr->bhr", qf, C)
        den = np.einsum("bhp,bhp->bh", qf, n)
        out[:, t] = num / np.maximum(np.abs(den), np.exp(-m_new))[..., None]
        m = m_new
    return out, (C, n, m)


@pytest.mark.parametrize("S,chunk", [(64, 16), (40, 16), (30, 8)])
def test_mlstm_chunked_matches_naive(S, chunk):
    rs = np.random.RandomState(2)
    B, H, P = 2, 2, 8
    q = rs.randn(B, S, H, P).astype(np.float32)
    k = rs.randn(B, S, H, P).astype(np.float32)
    v = rs.randn(B, S, H, P).astype(np.float32)
    ig = rs.randn(B, S, H).astype(np.float32)
    fg = rs.randn(B, S, H).astype(np.float32) + 2.0
    want, (C, n, m) = _mlstm_naive(q, k, v, ig, fg)
    got, (Cg, ng, mg) = mlstm_chunked(*map(jnp.asarray, (q, k, v, ig, fg)), chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-4, atol=5e-4)
    # states stored scaled by exp(-m): compare in true units
    np.testing.assert_allclose(
        np.asarray(Cg) * np.exp(np.asarray(mg))[..., None, None],
        C * np.exp(m)[..., None, None], rtol=5e-3, atol=1e-5)


@hypothesis.given(st.integers(1, 40), st.integers(2, 16))
@hypothesis.settings(deadline=None, max_examples=10)
def test_mlstm_step_equals_chunked_prefix(S, chunk):
    """Property: running the per-token recurrence S times == chunked form
    (any S, any chunk — exercises the ragged-padding path)."""
    rs = np.random.RandomState(S * 100 + chunk)
    B, H, P = 1, 2, 4
    q = rs.randn(B, S, H, P).astype(np.float32)
    k = rs.randn(B, S, H, P).astype(np.float32)
    v = rs.randn(B, S, H, P).astype(np.float32)
    ig = rs.randn(B, S, H).astype(np.float32)
    fg = rs.randn(B, S, H).astype(np.float32) + 1.0
    got, _ = mlstm_chunked(*map(jnp.asarray, (q, k, v, ig, fg)), chunk=chunk)
    state = (jnp.zeros((B, H, P, P)), jnp.zeros((B, H, P)), jnp.full((B, H), -1e30))
    outs = []
    for t in range(S):
        h, state = mlstm_step(*[jnp.asarray(a[:, t]) for a in (q, k, v, ig, fg)], state)
        outs.append(np.asarray(h))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(got), rtol=2e-3, atol=2e-3)


def test_slstm_state_carry():
    """Scanning in two halves with carried state == one scan."""
    rs = np.random.RandomState(3)
    B, S, H, P = 2, 20, 2, 4
    xg = (rs.randn(B, S, 4, H, P) * 0.5).astype(np.float32)
    R = (rs.randn(4, H, P, P) * 0.1).astype(np.float32)
    full, _ = slstm_scan(jnp.asarray(xg), jnp.asarray(R))
    h1, st1 = slstm_scan(jnp.asarray(xg[:, :10]), jnp.asarray(R))
    h2, _ = slstm_scan(jnp.asarray(xg[:, 10:]), jnp.asarray(R), state=st1)
    np.testing.assert_allclose(
        np.concatenate([np.asarray(h1), np.asarray(h2)], 1), np.asarray(full),
        rtol=1e-5, atol=1e-5)
