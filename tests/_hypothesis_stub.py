"""Fallback shim for the optional ``hypothesis`` dev dependency.

Property-based tests use hypothesis when it is installed (the ``dev``
extra in pyproject.toml). On a clean environment the real import fails;
test modules then fall back to this stub so that

* module collection succeeds (the seed repo errored at collection), and
* the plain (non-property) tests in the same module still run, while
* every ``@hypothesis.given(...)`` test is reported as *skipped*.

Usage in a test module::

    try:
        import hypothesis
        import hypothesis.strategies as st
    except ImportError:
        from _hypothesis_stub import hypothesis, st
"""
import pytest


class _Strategies:
    """Any ``st.<name>(...)`` call returns an inert placeholder."""

    def __getattr__(self, name):
        return lambda *args, **kwargs: None


class _HypothesisStub:
    HAVE_HYPOTHESIS = False

    @staticmethod
    def given(*args, **kwargs):
        def deco(fn):
            # Replace with a zero-arg test so pytest does not try to resolve
            # the strategy parameters as fixtures before the skip applies.
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():  # pragma: no cover
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    @staticmethod
    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco


hypothesis = _HypothesisStub()
st = _Strategies()
