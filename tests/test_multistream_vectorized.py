"""n-streams-per-lane slot accounting & load-aware parity (ISSUE 7;
sim/vectorized.py).

Three claims the multi-stream fast path must hold:

* **Slot accounting** — the scan's per-slot ``in_flight`` view (driving
  warm-validity masks, spread selection, ``load**alpha`` contention and
  load-aware judging) is maintained incrementally from stream state, never
  recounted. The collected rows expose the take/release event stream
  (``slot``/``t_start_ms``/``t_end_ms``/``load_at_start``), so an O(n)
  replay recomputes every dispatch's occupancy from scratch and compares —
  the same aggregate-vs-reference-scan pattern as
  tests/test_pool_fastpath.py, with hypothesis widening the config space
  when the dev extra is installed.
* **Load-aware parity** — concurrency-4 ``load**alpha`` arms on the
  gcf-gen2-loaded profile meet the same KS / ±pp bars as the plain
  closed-loop arms in tests/test_vectorized_parity.py (ISSUE acceptance:
  these arms were event-engine-only before the slot model).
* **Open-loop admission conservation** — with finite ``admit_bound`` /
  ``queue_capacity`` the in-scan pipeline loses nothing:
  ``arrived == completed + dropped + parked-at-end`` exactly, per seed
  (a dispatch resolves synchronously at its dispatch time, so "parked"
  subsumes in-flight: retries and deferrals waiting in the ring).
"""
import dataclasses
import math
import warnings

import numpy as np
import pytest
from scipy import stats
from scipy.stats import ks_2samp

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # pragma: no cover - dev extra absent
    from _hypothesis_stub import hypothesis, st

import repro.sim.vectorized as V
from repro.core.policy import MinosPolicy
from repro.sim import FaaSPlatform, FunctionSpec, PlatformProfile, VariationModel
from repro.sim.arrivals import PoissonProcess
from repro.sim.vectorized import (
    ORDER_CODES,
    arm_from_spec,
    run_event_chain,
    simulate_arms,
    simulate_open_arms,
    stack_arms,
)

SPEC = FunctionSpec(
    name="multistream", prepare_ms=600.0, body_ms=1500.0, benchmark_ms=300.0,
    cold_start_ms=250.0, recycle_lifetime_ms=8_000.0, contention_rho=0.95,
    benchmark_noise=0.08,
)
VM = VariationModel(sigma=0.15)
THINK_MS = 500.0
THRESHOLD = SPEC.benchmark_ms * math.exp(
    stats.norm.ppf(0.4) * math.sqrt(VM.sigma ** 2 + SPEC.benchmark_noise ** 2))


def _loaded_profile(**kw) -> PlatformProfile:
    prof = PlatformProfile.gcf_gen2_loaded(**kw)
    return dataclasses.replace(prof, recycle_lifetime_ms=8_000.0)


# ---------------------------------------------------------------------------
# Slot accounting: O(n) replay of the collected take/release event stream
# ---------------------------------------------------------------------------


def _replay_slot_loads(rows: dict, concurrency: int) -> int:
    """Recompute every dispatch's slot occupancy from scratch and compare
    with the scan's incremental ``load_at_start``.

    ``rows`` holds one seed's step-ordered records. A request on slot k is
    in flight on [t_start, t_end); the engine counts ``ended > t0``
    strictly, so the replay does too. Failed probes (slot == -1) hold no
    slot — the event engine judges and drops the instance synchronously at
    dispatch. Returns the number of verified dispatches."""
    slot = np.asarray(rows["slot"]).astype(int)
    t0 = np.asarray(rows["t_start_ms"], float)
    t1 = np.asarray(rows["t_end_ms"], float)
    load0 = np.asarray(rows["load_at_start"]).astype(int)
    cold = np.asarray(rows["served_by_cold"]).astype(bool)
    comp = np.asarray(rows["completed"]).astype(bool)
    # a step completes a request iff it holds a slot
    np.testing.assert_array_equal(slot >= 0, comp)
    # the scan fires streams in event-loop order: time never runs backwards
    assert np.all(np.diff(t0) >= 0.0)
    checked = 0
    for i in range(len(slot)):
        if slot[i] < 0:
            continue
        ref = int(np.sum((slot[:i] == slot[i]) & (t1[:i] > t0[i])))
        if cold[i]:
            # cold placement picked a dead slot: must be empty
            assert ref == 0, (i, slot[i], ref)
        else:
            assert ref == load0[i], (i, slot[i], ref, load0[i])
            # warm takes respect per-instance capacity
            assert ref + 1 <= concurrency, (i, ref, concurrency)
        checked += 1
    return checked


def _slot_arm(concurrency: int, alpha: float, order: str, gate: str):
    arm = arm_from_spec(
        SPEC, VM,
        profile=_loaded_profile(concurrency=concurrency, alpha=alpha),
        gate=gate, threshold=THRESHOLD, think_time_ms=THINK_MS)
    return arm._replace(order=ORDER_CODES[order])


@pytest.mark.parametrize("order", ["lifo", "fifo", "spread"])
@pytest.mark.parametrize("concurrency", [1, 4])
def test_slot_loads_equal_replay_seeded(order, concurrency):
    arms = stack_arms([_slot_arm(concurrency, 0.6, order, g)
                       for g in ("off", "fixed")])
    res = simulate_arms(arms, seeds=range(3), n_steps=400, n_streams=4,
                        collect_requests=True)
    total = 0
    for a in range(res.n_arms):
        for s in range(res.n_seeds):
            total += _replay_slot_loads(
                {k: v[a][s] for k, v in res.requests.items()}, concurrency)
    assert total > 0


@hypothesis.given(
    concurrency=st.integers(min_value=1, max_value=4),
    alpha=st.floats(min_value=0.0, max_value=0.8),
    order=st.sampled_from(["lifo", "fifo", "spread"]),
    frac_mult=st.floats(min_value=0.5, max_value=1.5),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
@hypothesis.settings(deadline=None, max_examples=25)
def test_slot_loads_equal_replay_property(concurrency, alpha, order,
                                          frac_mult, seed):
    """Arm parameters are scan *inputs*, not static config, so every
    example reuses one compiled kernel (fixed n_steps / n_streams)."""
    arm = _slot_arm(concurrency, alpha, order, "fixed")
    arm = arm._replace(threshold=float(arm.threshold) * frac_mult)
    res = simulate_arms(stack_arms([arm]), seeds=[seed], n_steps=200,
                        n_streams=4, collect_requests=True)
    _replay_slot_loads({k: v[0][0] for k, v in res.requests.items()},
                       concurrency)


# ---------------------------------------------------------------------------
# Load-aware parity: concurrency-4 load**alpha arms vs the event engine
# ---------------------------------------------------------------------------

LA_N_REQUESTS = 600
LA_N_VUS = 8
LA_EVENT_SEEDS = range(60)   # the event engine is cheap at this size; the
LA_VEC_SEEDS = range(64)     # sample mass keeps the ±1pp bar meaningful


@pytest.fixture(scope="module")
def loaded_runs():
    """gcf-gen2-loaded (concurrency 4, alpha 0.6, load-aware gate), both
    engines, gate off vs fixed."""
    prof = _loaded_profile()
    event = {}
    for gate in ("off", "fixed"):
        pol = MinosPolicy(elysium_threshold=float("inf"), enabled=False) \
            if gate == "off" \
            else MinosPolicy(elysium_threshold=THRESHOLD, max_retries=5)
        an, lat, nterm, nprobe = [], [], 0, 0
        for seed in LA_EVENT_SEEDS:
            plat = FaaSPlatform(SPEC, VM, pol, seed=seed, profile=prof)
            rs = run_event_chain(plat, LA_N_REQUESTS, THINK_MS,
                                 n_vus=LA_N_VUS)
            an += [r.analysis_ms for r in rs]
            lat += [r.latency_ms for r in rs]
            nterm += plat.instances_terminated
            nprobe += len(plat.benchmark_observations)
        event[gate] = {"analysis": np.asarray(an), "latency": np.asarray(lat),
                       "pass_rate": 1.0 - nterm / max(nprobe, 1)}
    arms = stack_arms([
        arm_from_spec(SPEC, VM, profile=prof, gate=g, threshold=THRESHOLD,
                      think_time_ms=THINK_MS) for g in ("off", "fixed")])
    res = simulate_arms(arms, seeds=LA_VEC_SEEDS, n_steps=LA_N_REQUESTS,
                        n_streams=LA_N_VUS, collect_requests=True)
    vec = {}
    for i, g in enumerate(("off", "fixed")):
        # retry-as-step: rows with completed=False are attempt records
        comp = np.asarray(res.requests["completed"][i]).astype(bool)
        vec[g] = {
            "analysis": np.asarray(res.requests["analysis_ms"][i])[comp],
            "latency": np.asarray(res.requests["latency_ms"][i])[comp],
            "pass_rate": float(res.summary["pass_rate"][i].mean()),
        }
    return event, vec


@pytest.mark.parametrize("gate", ("off", "fixed"))
def test_loaded_ks_distributions(loaded_runs, gate):
    """Same D-statistic bound rationale as tests/test_vectorized_parity.py;
    measured D at these pinned seeds is 0.020–0.027."""
    event, vec = loaded_runs
    for field in ("analysis", "latency"):
        ks = ks_2samp(event[gate][field], vec[gate][field])
        assert ks.statistic < 0.06, (gate, field, ks)


def test_loaded_pass_rate_within_2pp(loaded_runs):
    event, vec = loaded_runs
    d = abs(event["fixed"]["pass_rate"] - vec["fixed"]["pass_rate"])
    assert d < 0.02, (event["fixed"]["pass_rate"], vec["fixed"]["pass_rate"])


def test_loaded_speedup_within_1pp(loaded_runs):
    """Gated-vs-baseline improvement matches under self-contention — the
    gate's benefit here flows through occupancy (fewer slow instances →
    less queueing → lower load multiplier), so this is the end-to-end
    check that the slot model feeds back like the event pool."""
    event, vec = loaded_runs
    imp_ev = 1.0 - (event["fixed"]["analysis"].mean()
                    / event["off"]["analysis"].mean())
    imp_vec = 1.0 - (vec["fixed"]["analysis"].mean()
                     / vec["off"]["analysis"].mean())
    assert abs(imp_ev - imp_vec) < 0.01, (imp_ev, imp_vec)


# ---------------------------------------------------------------------------
# Open-loop admission: drop/defer conservation in-scan
# ---------------------------------------------------------------------------


def _open_res(arm, *, n_servers=2, n_steps=240, seeds=range(6), rate=0.9):
    proc = PoissonProcess(rate)
    iats = np.stack([proc.iats_ms(np.random.RandomState(5000 + i), n_steps)
                     for i in seeds])
    return simulate_open_arms(stack_arms([arm]), seeds=seeds, iats_ms=iats,
                              n_servers=n_servers, collect_requests=True)


def _assert_conserved(res, arm_idx=0):
    s = {k: np.asarray(v[arm_idx]) for k, v in res.summary.items()}
    np.testing.assert_array_equal(
        s["n_requests"],
        s["n_completed"] + s["n_dropped"] + s["n_parked_end"])
    return s


def _gen1_arm(**kw):
    prof = dataclasses.replace(PlatformProfile.gcf_gen1(),
                               recycle_lifetime_ms=8_000.0)
    return arm_from_spec(SPEC, VM, profile=prof, gate="fixed",
                         threshold=THRESHOLD, think_time_ms=0.0, **kw)


def test_open_defer_conserves_and_counts():
    """Finite admit_bound: a 2-server pool at rho≈0.9 defers heavily; every
    deferral re-offers (parks, then drains) — nothing is lost and nothing
    is dropped. Deferral must also not fabricate latency: the deferred
    request's wait is back-dated to its arrival."""
    res = _open_res(_gen1_arm(admit_bound=4.0))
    s = _assert_conserved(res)
    assert s["n_deferred"].sum() > 0
    assert s["n_dropped"].sum() == 0
    comp = np.asarray(res.requests["completed"][0]).astype(bool)
    deferred = np.asarray(res.requests["deferred"][0]).astype(bool)
    assert deferred.sum() > 0
    # a row is exactly one outcome
    dropped = np.asarray(res.requests["dropped"][0]).astype(bool)
    assert not np.any(comp & (deferred | dropped))
    # deferred-then-completed requests carry their full wait: their queue
    # wait is at least the service they had to let finish first
    wait = np.asarray(res.requests["wait_ms"][0], float)
    assert float(wait[comp].max()) > 0.0


def test_open_drop_conserves_and_counts():
    """Finite queue_capacity: overload sheds arrivals; the drop counter,
    the per-row dropped flags and the conservation identity all agree."""
    res = _open_res(_gen1_arm()._replace(queue_capacity=3.0))
    s = _assert_conserved(res)
    n_drop = s["n_dropped"].sum()
    assert n_drop > 0
    dropped = np.asarray(res.requests["dropped"][0]).astype(bool)
    assert dropped.sum() == n_drop
    assert float(s["drop_rate"].mean()) == pytest.approx(
        n_drop / s["n_requests"].sum(), abs=1e-6)


def test_open_unbounded_never_drops_or_defers():
    res = _open_res(_gen1_arm(), n_servers=4)
    s = _assert_conserved(res)
    assert s["n_deferred"].sum() == 0 and s["n_dropped"].sum() == 0


def test_open_queue_capacity_beyond_ring_raises():
    arm = _gen1_arm()._replace(queue_capacity=99.0)
    with pytest.raises(ValueError, match="queue_ring"):
        _open_res(arm)


# ---------------------------------------------------------------------------
# Satellite: think_time_ms contract of the open-loop scan
# ---------------------------------------------------------------------------


def test_open_think_time_warns_once_per_process(monkeypatch):
    """simulate_open_arms ignores ArmParams.think_time_ms (arrivals come
    from iats_ms): a non-zero value warns once per process, then stays
    silent; a zero value never warns."""
    monkeypatch.setattr(V, "_OPEN_THINK_WARNED", False)
    arm = _gen1_arm()._replace(think_time_ms=750.0)
    with pytest.warns(UserWarning, match="think_time_ms"):
        _open_res(arm, n_steps=20, seeds=range(1))
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second call must stay silent
        _open_res(arm, n_steps=20, seeds=range(1))
    monkeypatch.setattr(V, "_OPEN_THINK_WARNED", False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # zero think time never warns
        _open_res(_gen1_arm(), n_steps=20, seeds=range(1))
