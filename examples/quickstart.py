"""Quickstart: the Minos loop in 60 lines.

1. Pre-test a fleet to set the elysium threshold (paper §III-A).
2. Deploy a policy; cold instances benchmark themselves and either join the
   known-good pool or requeue-and-crash.
3. Watch the pool outperform the platform average.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import MinosPolicy, Pricing, run_pretest
from repro.sim import FaaSPlatform, FunctionSpec, VariationModel, run_closed_loop

SEED = 0

# A platform with hefty co-tenancy variation (lognormal sigma 0.2).
variation = VariationModel(sigma=0.2)
spec = FunctionSpec(name="demo", prepare_ms=800, body_ms=1500, benchmark_ms=300,
                    recycle_lifetime_ms=None, contention_rho=1.0, benchmark_noise=0.0)
pricing = Pricing.gcf(256)

# --- 1. pre-testing: observe cold-start probes with Minos disabled --------
disabled = MinosPolicy(elysium_threshold=float("inf"), enabled=False)
probe_plat = FaaSPlatform(spec, variation, disabled, pricing, seed=SEED)
run_closed_loop(probe_plat, n_vus=10, duration_ms=60_000)
probes = [spec.benchmark_ms / r.instance_speed
          for r in probe_plat.results if r.served_by_cold]
report = run_pretest(probes, pass_fraction=0.4)  # 60th percentile gate
print(f"pre-test: n={report.n_samples} mean={report.mean:.0f}ms "
      f"p50={report.p50:.0f}ms -> elysium threshold {report.threshold:.0f}ms")

# --- 2. deploy Minos -------------------------------------------------------
policy = MinosPolicy(elysium_threshold=report.threshold, max_retries=5)
minos = FaaSPlatform(spec, variation, policy, pricing, seed=SEED + 1)
base = FaaSPlatform(spec, variation, disabled, pricing, seed=SEED + 1)
m_res = run_closed_loop(minos, n_vus=10, duration_ms=10 * 60_000)
b_res = run_closed_loop(base, n_vus=10, duration_ms=10 * 60_000)

# --- 3. compare ------------------------------------------------------------
m_analysis = np.mean([r.analysis_ms for r in m_res])
b_analysis = np.mean([r.analysis_ms for r in b_res])
print(f"baseline: {len(b_res)} requests, analysis {b_analysis:.0f}ms, "
      f"${base.cost.cost_per_million_successful():.2f}/M")
print(f"minos:    {len(m_res)} requests, analysis {m_analysis:.0f}ms, "
      f"${minos.cost.cost_per_million_successful():.2f}/M "
      f"({minos.instances_terminated} instances terminated)")
print(f"analysis step improvement: {(1 - m_analysis / b_analysis) * 100:.1f}%")
assert m_analysis < b_analysis
