"""Serving driver: batch of requests against a real (reduced) model behind
the Minos replica gate vs. an ungated baseline — the FaaS->TPU-serving
adaptation of the paper (DESIGN.md §2).

Run: PYTHONPATH=src python examples/serve_minos.py [--arch qwen3-0.6b]
"""
import argparse

import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.cost import Pricing
from repro.core.elysium import pretest_threshold
from repro.core.policy import MinosPolicy
from repro.serving.engine import MinosServingEngine, ServeRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    pricing = Pricing.tpu_chip_seconds(chips=4)
    rs = np.random.RandomState(0)
    reqs = [
        ServeRequest(prompt=rs.randint(0, cfg.vocab, size=16).astype(np.int32),
                     max_new_tokens=8, request_id=i)
        for i in range(args.requests)
    ]

    # pre-test: sample replica speeds to set the elysium threshold
    probe_work = 200.0
    speeds = np.exp(rs.normal(0.0, 0.15, size=64))
    thr = pretest_threshold(probe_work / speeds, pass_fraction=0.4)
    print(f"elysium threshold: {thr:.0f}ms (probe {probe_work:.0f}ms at unit speed)")

    results = {}
    for name, policy in (
        ("baseline", MinosPolicy(elysium_threshold=0.0, enabled=False)),
        ("minos", MinosPolicy(elysium_threshold=thr, max_retries=5)),
    ):
        eng = MinosServingEngine(cfg, policy, pricing, seed=1, max_pool=4)
        res = eng.serve(list(reqs))
        tput = [r.sim_duration_ms for r in res]
        results[name] = res
        print(
            f"{name:9s}: {len(res)} served | replicas started {eng.replicas_started} "
            f"terminated {eng.replicas_terminated} | pool speed "
            f"{eng.pool_mean_speed:.3f} | mean req {np.mean(tput):.0f}ms | "
            f"cost ${eng.cost.total:.4f}"
        )

    # identical outputs regardless of gating (selection changes WHERE, not WHAT)
    for a, b in zip(results["baseline"], results["minos"]):
        assert np.array_equal(a.tokens, b.tokens), "serving must be deterministic"
    print("outputs identical across arms ✓ (instance selection is "
          "performance-transparent)")


if __name__ == "__main__":
    main()
