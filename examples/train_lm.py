"""End-to-end training driver: train a reduced llama3-family model on the
synthetic bigram-structured token stream for a few hundred steps and watch
the loss fall well below the unigram entropy floor.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

import numpy as np

from repro.checkpoint.ckpt import save
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import TokenStream
from repro.train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--ckpt", default="/tmp/repro_lm.npz")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, n_layers=4, d_model=256, vocab=512)
    print(f"training {cfg.arch_id} (reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"V={cfg.vocab}; {cfg.param_count()/1e6:.1f}M params) "
          f"for {args.steps} steps")

    data = iter(TokenStream(vocab=cfg.vocab, batch=8, seq_len=128, seed=0))
    tc = TrainConfig(peak_lr=1e-3, warmup_steps=20, total_steps=args.steps)

    def log(step, m):
        print(f"  step {step:4d}  loss {m['loss']:.3f}  nll {m['nll']:.3f}  "
              f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}  "
              f"({m['wall_s']:.0f}s)")

    params, hist = train(cfg, data, tc, steps=args.steps, log_every=25, log_fn=log)
    first, last = hist[0]["nll"], hist[-1]["nll"]
    uniform = np.log(cfg.vocab)
    print(f"\nnll: {first:.2f} -> {last:.2f} (uniform floor {uniform:.2f})")
    assert last < first * 0.7, "training should reduce loss"
    save(args.ckpt, params)
    print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
