"""End-to-end driver: the paper's weather data-processing workload, with the
function body executed for real — CSV download (simulated network) + parse +
closed-form linear regression in JAX — behind the Minos gate, where the
probe is the Pallas matmul kernel.

This is the paper's exact evaluation scenario (§III): while the CSV
downloads (network-bound), the CPU probe runs; slow instances crash and
requeue; the regression runs on the surviving fast pool.

Run: PYTHONPATH=src python examples/weather_workflow.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import MatmulProbe, MinosPolicy, Pricing, pretest_threshold
from repro.data.pipeline import make_weather_csv, parse_weather_csv
from repro.sim import FaaSPlatform, FunctionSpec, VariationModel, run_closed_loop


def analyze(csv_text: str) -> np.ndarray:
    """The paper's 'analysis' step: predict tomorrow's temperature with a
    closed-form least-squares solve (in JAX)."""
    X, y = parse_weather_csv(csv_text)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    coef, *_ = jnp.linalg.lstsq(Xj, yj)
    return np.asarray(coef)


def main() -> None:
    # --- the function body, run for real once per simulated request class --
    csv_text = make_weather_csv(n_rows=730, seed=1)  # two years of history
    t0 = time.perf_counter()
    coef = analyze(csv_text)
    t_real = (time.perf_counter() - t0) * 1e3
    print(f"linear regression coefficients: {np.round(coef, 3)}")
    print(f"  (ground truth: [0.8, -3.0, 0.02, -0.1, +intercept]; "
          f"real JAX solve took {t_real:.1f}ms)")
    err = np.abs(coef[:4] - np.array([0.8, -3.0, 0.02, -0.1]))
    assert (err < 0.2).all(), "regression should recover the generator"

    # --- the probe the instances run (Pallas matmul kernel, ref [10]) ------
    probe = MatmulProbe(n=256, repeats=2)
    t0 = time.perf_counter()
    probe.run()
    print(f"matmul probe (pallas, interpret): {(time.perf_counter()-t0)*1e3:.0f}ms "
          f"= {probe.flops/1e6:.0f} MFLOP")

    # --- the full workflow under Minos on the simulated platform -----------
    variation = VariationModel(sigma=0.18)
    spec = FunctionSpec(name="weather", prepare_ms=1500, body_ms=1800,
                        benchmark_ms=450)
    pricing = Pricing.gcf(256)
    thr = pretest_threshold(
        [spec.benchmark_ms / variation.sample_speed(np.random.RandomState(9), 0)
         for _ in range(100)], pass_fraction=0.4)
    minos = FaaSPlatform(spec, variation,
                         MinosPolicy(elysium_threshold=thr), pricing, seed=3)
    base = FaaSPlatform(spec, variation,
                        MinosPolicy(elysium_threshold=0, enabled=False), pricing, seed=3)
    m = run_closed_loop(minos, n_vus=10, duration_ms=10 * 60_000)
    b = run_closed_loop(base, n_vus=10, duration_ms=10 * 60_000)
    mi = np.mean([r.analysis_ms for r in m])
    bi = np.mean([r.analysis_ms for r in b])
    print(f"\nworkflow: baseline {len(b)} req / analysis {bi:.0f}ms | "
          f"minos {len(m)} req / analysis {mi:.0f}ms "
          f"(+{(1-mi/bi)*100:.1f}%, {minos.instances_terminated} terminated)")
    print(f"cost: ${base.cost.cost_per_million_successful():.2f}/M -> "
          f"${minos.cost.cost_per_million_successful():.2f}/M")


if __name__ == "__main__":
    main()
