"""Multi-model pipeline serving: whisper-small (ASR) → llama3.2-1b (gen),
each stage behind its own Minos replica gate, on the unified execution
substrate (DESIGN.md §9). Gated vs ungated arms run the same items with the
same weights — instance selection changes WHERE work runs, never WHAT it
computes.

Run: PYTHONPATH=src python examples/pipeline_serve.py [--items 8]
"""
import argparse

import numpy as np

from repro.serving.pipeline import (
    PipelineSpec,
    build_asr_llm_pipeline,
    pipeline_arm_factory,
    pipeline_pricing,
)
from repro.sim.variation import VariationModel
from repro.sim.workflow_dag import WorkflowEngine, run_workflow_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = PipelineSpec()
    dag, backends = build_asr_llm_pipeline(spec, seed=args.seed)
    vm = VariationModel(sigma=spec.speed_sigma)
    print(f"pipeline: {' -> '.join(dag.order)} "
          f"({backends['asr'].cfg.arch_id} -> {backends['llm'].cfg.arch_id}), "
          f"replica pool cap {spec.max_pool}/stage")

    runs = {}
    for arm in ("disabled", "fixed"):
        eng = WorkflowEngine(dag, vm, pipeline_arm_factory(arm),
                             pricing=pipeline_pricing(), seed=args.seed + 3)
        run = run_workflow_batch(eng, n_items=args.items, inter_arrival_ms=400.0,
                                 payload_fn=lambda i: {"audio_id": i})
        runs[arm] = run
        pool_speeds = {n: np.mean(p.pool.speeds) if p.pool.speeds else float("nan")
                       for n, p in eng.platforms.items()}
        print(
            f"{arm:9s}: {run.n_items} items | mean latency "
            f"{run.mean_item_latency_ms:.0f}ms | body {run.mean_item_analysis_ms:.0f}ms | "
            f"replicas started {eng.instances_started} terminated "
            f"{eng.instances_terminated} | pool speeds "
            + " ".join(f"{n}={s:.3f}" for n, s in pool_speeds.items())
            + f" | cost ${run.cost.total:.4f}"
        )

    # identical outputs regardless of gating (selection is performance-transparent);
    # completion ORDER may differ across arms (retries), so match by item id
    for arm_runs in (runs["disabled"], runs["fixed"]):
        arm_runs.items.sort(key=lambda it: it.item_id)
    for a, b in zip(runs["disabled"].items, runs["fixed"].items):
        assert a.item_id == b.item_id
        assert np.array_equal(a.stage_results["llm"].output,
                              b.stage_results["llm"].output)
    print("outputs identical across arms ✓ (instance selection is "
          "performance-transparent)")


if __name__ == "__main__":
    main()
