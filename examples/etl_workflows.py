"""The 3-/5-/7-stage ETL workflow scenarios on three platform models.

Demonstrates the workflow DAG engine (DESIGN.md §5): each stage is its own
deployed function with its own Minos-gated warm pool; fan-out stages run in
parallel and fan-in stages wait for ALL parents (the 5- and 7-stage DAGs
exercise the barrier). Three arms per workflow:

* disabled — no gate (baseline);
* fixed    — per-stage pre-tested elysium threshold (paper §III-A);
* adaptive — per-stage online threshold, no pre-test phase (paper §IV).

Run: PYTHONPATH=src python examples/etl_workflows.py [--platform gcf-gen1]
"""
import argparse

from repro.sim import (
    PlatformProfile,
    VariationModel,
    WorkflowEngine,
    WorkflowSummary,
    etl_suite,
    improvement,
    run_workflow_closed_loop,
    workflow_arm_factory,
)

PROFILES = {
    "gcf-gen1": PlatformProfile.gcf_gen1,
    "gcf-gen2": PlatformProfile.gcf_gen2,
    "lambda": PlatformProfile.aws_lambda,
}


def ascii_dag(dag) -> str:
    lines = []
    for name in dag.order:
        deps = dag.stages[name].deps
        lines.append(f"  {name}" + (f"  <- {', '.join(deps)}" if deps else "  (source)"))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--platform", default="gcf-gen1", choices=sorted(PROFILES))
    ap.add_argument("--minutes", type=float, default=10.0, help="simulated window")
    ap.add_argument("--sigma", type=float, default=0.18, help="contention spread")
    args = ap.parse_args()

    profile = PROFILES[args.platform]()
    vm = VariationModel(sigma=args.sigma)
    duration_ms = args.minutes * 60 * 1000.0

    for name, dag in etl_suite().items():
        print(f"\n=== {name} on {profile.name} "
              f"({len(dag)} stages, sources={dag.sources}, sinks={dag.sinks}) ===")
        print(ascii_dag(dag))
        base_lat = None
        for arm in ("disabled", "fixed", "adaptive"):
            engine = WorkflowEngine(
                dag, vm,
                workflow_arm_factory(arm, vm, pricing=profile.pricing),
                profile=profile, seed=42,
            )
            run = run_workflow_closed_loop(engine, n_vus=10, duration_ms=duration_ms)
            s = WorkflowSummary.from_run(arm, run)
            if arm == "disabled":
                base_lat = s.mean_item_latency_ms
                extra = ""
            else:
                extra = f"  speedup {improvement(base_lat, s.mean_item_latency_ms)*100:+.1f}%"
            print(f"  {arm:9s} items={s.n_items:5d}  "
                  f"latency={s.mean_item_latency_ms/1000:6.2f}s  "
                  f"${s.cost_per_million_items:7.2f}/M items  "
                  f"terminated={s.n_terminated:4d}{extra}")


if __name__ == "__main__":
    main()
