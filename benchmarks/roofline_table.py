"""Aggregate the dry-run JSON results into the §Roofline table."""
from __future__ import annotations

import json
import pathlib

RESULTS_DIR = pathlib.Path(__file__).resolve().parent / "results"


def load_results(multi_pod: bool | None = None) -> list[dict]:
    rows = []
    for f in sorted(RESULTS_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        if multi_pod is not None and r.get("multi_pod") != multi_pod:
            continue
        rows.append(r)
    return rows


def roofline_rows(multi_pod: bool | None = False) -> list[dict]:
    out = []
    for r in load_results(multi_pod):
        tag = r.get("tag", "") or "baseline"
        if r["status"] != "ok":
            out.append({
                "arch": r["arch"], "shape": r["shape"],
                "mesh": "2pod" if r.get("multi_pod") else "1pod",
                "variant": tag, "status": r["status"],
                "t_compute_s": "", "t_memory_s": "", "t_collective_s": "",
                "bottleneck": r.get("reason", r.get("error", ""))[:60],
                "useful_flops_pct": "", "hbm_gib_per_dev": "",
            })
            continue
        rf = r["roofline"]
        m = r["memory"]
        out.append({
            "arch": r["arch"], "shape": r["shape"],
            "mesh": "2pod" if r.get("multi_pod") else "1pod",
            "variant": tag, "status": "ok",
            "t_compute_s": f"{rf['t_compute_s']:.3e}",
            "t_memory_s": f"{rf['t_memory_s']:.3e}",
            "t_collective_s": f"{rf['t_collective_s']:.3e}",
            "bottleneck": rf["bottleneck"],
            "useful_flops_pct": f"{rf['useful_flops_ratio']*100:.0f}",
            "hbm_gib_per_dev": f"{((m['argument_bytes'] or 0)+(m['temp_bytes'] or 0))/2**30:.1f}",
        })
    return out


def markdown_table(rows: list[dict]) -> str:
    if not rows:
        return "(no dry-run results yet)"
    cols = ["arch", "shape", "mesh", "variant", "t_compute_s", "t_memory_s",
            "t_collective_s", "bottleneck", "useful_flops_pct", "hbm_gib_per_dev"]
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(lines)


def roofline_table(quick=True):
    rows = roofline_rows(multi_pod=False)
    ok = [r for r in rows if r["status"] == "ok"]
    headline = f"{len(ok)}/{len(rows)}_combos_ok" if rows else "no_results"
    return rows, headline


if __name__ == "__main__":
    print(markdown_table(roofline_rows(None)))
