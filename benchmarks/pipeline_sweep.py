"""Gated-vs-ungated sweep of the whisper→llama serving pipeline
(EXPERIMENTS.md §Pipeline sweep / §Load-aware pipeline sweep).

The paper's §V workflow argument on REAL model compute: both stages keep a
Minos-gated replica pool, the fast pools are re-used across every item, and
the sweep reports end-to-end item latency, body (compute) time, and cost
per item for each arm. Model outputs are asserted identical across arms.

``--load-aware`` runs the DESIGN.md §9 load model at hundreds of items:
replicas serve 4 concurrent streams with a real self-contention curve
(load**alpha) and the gate judges probes at live pool occupancy. This scale
is only reachable because the decode path is jitted (one compiled scan per
shape bucket instead of per-token Python dispatches); the sweep measures
the jitted-vs-eager wall time on a representative request and ASSERTS the
jitted path was hit for every body (``eager_calls == 0``) and is at least
5× faster — the CI guard that keeps the eager fallback from silently
regressing.

Usage: PYTHONPATH=src python benchmarks/pipeline_sweep.py
           [--quick|--smoke] [--load-aware]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.serving.pipeline import (
    PIPELINE_ARMS,
    PipelineSpec,
    build_asr_llm_pipeline,
    pipeline_arm_factory,
    pipeline_controller_factory,
    pipeline_pricing,
)
from repro.serving.backend import ServeRequest
from repro.sim.variation import VariationModel
from repro.sim.workflow_dag import WorkflowEngine, run_workflow_batch


def pipeline_sweep(quick: bool = False, *, n_items: int | None = None,
                   seeds: tuple[int, ...] | None = None,
                   spec: PipelineSpec | None = None,
                   inter_arrival_ms: float = 400.0):
    spec = spec or PipelineSpec()
    n_items = n_items if n_items is not None else (12 if quick else 30)
    seeds = seeds if seeds is not None else ((3,) if quick else (3, 4))
    vm = VariationModel(sigma=spec.speed_sigma)
    dag, backends = build_asr_llm_pipeline(spec, seed=0)  # weights shared by all arms

    rows = []
    agg: dict[str, dict[str, float]] = {}
    outputs: dict[str, list] = {}
    for arm in PIPELINE_ARMS:
        lat, body, cost, term = [], [], [], []
        for seed in seeds:
            eng = WorkflowEngine(dag, vm, pipeline_arm_factory(arm),
                                 pricing=pipeline_pricing(), seed=seed)
            run = run_workflow_batch(eng, n_items=n_items,
                                     inter_arrival_ms=inter_arrival_ms,
                                     payload_fn=lambda i: {"audio_id": i})
            run.items.sort(key=lambda it: it.item_id)
            if seed == seeds[0]:
                outputs[arm] = [it.stage_results["llm"].output for it in run.items]
            lat.append(run.mean_item_latency_ms)
            body.append(run.mean_item_analysis_ms)
            cost.append(run.cost.total / max(1, run.n_items))
            term.append(eng.instances_terminated)
        agg[arm] = {
            "latency_ms": float(np.mean(lat)),
            "body_ms": float(np.mean(body)),
            "cost_per_item": float(np.mean(cost)),
            "terminated": float(np.mean(term)),
        }
        rows.append({
            "arm": arm,
            "items": n_items,
            "mean_item_ms": round(agg[arm]["latency_ms"], 1),
            "mean_body_ms": round(agg[arm]["body_ms"], 1),
            "cost_per_item_usd": round(agg[arm]["cost_per_item"], 6),
            "terminated": round(agg[arm]["terminated"], 1),
        })

    # instance selection is performance-transparent: identical tokens per item
    for arm in PIPELINE_ARMS[1:]:
        for a, b in zip(outputs[PIPELINE_ARMS[0]], outputs[arm]):
            assert np.array_equal(a, b), "pipeline outputs must not depend on gating"

    base = agg["disabled"]
    body_gain = (base["body_ms"] - agg["fixed"]["body_ms"]) / base["body_ms"]
    lat_gain = (base["latency_ms"] - agg["fixed"]["latency_ms"]) / base["latency_ms"]
    cost_ratio = agg["fixed"]["cost_per_item"] / base["cost_per_item"]
    headline = (
        f"gated_body_gain={body_gain*100:.1f}%_latency_gain={lat_gain*100:.1f}%"
        f"_cost_ratio={cost_ratio:.2f}_outputs_identical=True"
    )
    return rows, headline, agg, backends


def load_aware_sweep(smoke: bool = False):
    """The load-aware arm (EXPERIMENTS.md §Load-aware pipeline sweep):
    concurrency-4 replicas, load**0.6 self-contention, load-aware gating,
    hundreds of items pushed hard enough that streams actually share
    replicas. Returns (rows, headline)."""
    spec = PipelineSpec(
        per_instance_concurrency=4,
        load_slowdown_alpha=0.6,
        gate_load_aware=True,
        **(dict(transcript_tokens=3, answer_tokens=4, max_pool=3) if smoke else {}),
    )
    n_items = 200 if smoke else 240
    rows, headline, agg, backends = pipeline_sweep(
        quick=True, n_items=n_items, seeds=(3,), spec=spec,
        inter_arrival_ms=50.0,  # pressure: streams must share replicas
    )

    # -- CI guards ------------------------------------------------------
    # (1) every body went through the compiled path; the eager loop ran 0×
    for name, be in backends.items():
        assert be.jit_stats["eager_calls"] == 0, (
            f"stage {name!r} fell back to eager decode: {be.jit_stats}")
        assert be.jit_stats["jit_calls"] >= n_items, (
            f"stage {name!r} jitted path under-hit: {be.jit_stats}")
    # (2) the jitted decode is demonstrably faster than the eager baseline
    llm = backends["llm"]
    req = ServeRequest(prompt=np.arange(1, 1 + spec.transcript_tokens,
                                        dtype=np.int32),
                       max_new_tokens=spec.answer_tokens)
    eager_ms = llm.time_model_ms(req, mode="eager", repeats=1)
    jit_ms = llm.time_model_ms(req, mode="jit", repeats=5)
    speedup = eager_ms / jit_ms
    assert speedup >= 5.0, (
        f"jitted decode must beat the eager baseline (got {speedup:.1f}x)")
    # (3) the gate earns its keep under load: gated arms beat disabled on
    # body (compute) latency
    assert agg["fixed"]["body_ms"] < agg["disabled"]["body_ms"], (
        "fixed-gated arm must beat disabled on body latency under load")

    compiles = sum(b.jit_stats["bucket_compiles"] for b in backends.values())
    headline += (
        f"_items={n_items}_jit_decode_speedup={speedup:.1f}x"
        f"_eager_ms={eager_ms:.0f}_jit_ms={jit_ms:.1f}_bucket_compiles={compiles}"
    )
    return rows, headline


def admission_sweep(quick: bool = False, *, smoke: bool = False,
                    n_items: int | None = None, seed: int = 3,
                    headroom: float = 1.0):
    """The ``--controllers`` arm (EXPERIMENTS.md §Controller sweep):
    static vs queue-aware per-stage admission on the load-aware pipeline.

    Both arms gate identically (adaptive §IV policy through the classic
    controller); they differ only in who answers ``on_admit``:

    * ``static`` — ``Stage.max_in_flight`` (here: unbounded, the PR 2/3
      default) via the classic controller;
    * ``queue-aware`` — :class:`~repro.core.control.
      QueueAwareAdmissionController`: items wait at admission while the
      stage's in-flight + queued demand exceeds ``headroom ×`` its
      certified capacity (replica budget × streams).

    Under pressure (30 ms inter-arrival, tiny replica budget) the elastic
    cold-start supply makes overload show up as replica churn, not queue
    depth: the static arm spawns instances far past the pool cap, pays a
    probe + gate decision for each and despawns them at release. The
    dynamic bound keeps the work on the certified pool: the headline is
    the replica-churn and cost-per-item reduction; mean item latency
    RISES (deferred items wait) — the honest trade-off, recorded in
    EXPERIMENTS.md. Asserts the cost/churn win so CI catches regressions.

    Protocol note: the spec pins SHORT decodes (3/4 tokens) at every
    scale — the churn-dominated regime where admission is the right
    lever. With long decodes the trade inverts: concentrating load on
    fewer replicas inflates every body via ``load**alpha`` by more than
    the spawn churn it saves (measured in EXPERIMENTS.md §Controller
    sweep) — admission control is a churn tool, not a universal win.
    """
    spec = PipelineSpec(
        per_instance_concurrency=4,
        load_slowdown_alpha=0.6,
        gate_load_aware=True,
        transcript_tokens=3, answer_tokens=4, max_pool=3,
    )
    n_items = n_items if n_items is not None else \
        (120 if smoke else (160 if quick else 240))
    vm = VariationModel(sigma=spec.speed_sigma)
    dag, backends = build_asr_llm_pipeline(spec, seed=0)

    rows = []
    agg: dict[str, dict[str, float]] = {}
    for arm in ("static", "queue-aware"):
        eng = WorkflowEngine(
            dag, vm,
            controller_factory=pipeline_controller_factory(
                arm, headroom=headroom),
            pricing=pipeline_pricing(), seed=seed)
        run = run_workflow_batch(eng, n_items=n_items, inter_arrival_ms=30.0,
                                 payload_fn=lambda i: {"audio_id": i})
        defers = sum(getattr(p.controller, "deferred", 0)
                     for p in eng.platforms.values())
        agg[arm] = {
            "latency_ms": run.mean_item_latency_ms,
            "cost_per_item": run.cost.total / max(1, run.n_items),
            "started": eng.instances_started,
            "terminated": eng.instances_terminated,
        }
        rows.append({
            "arm": arm,
            "items": run.n_items,
            "mean_item_ms": round(run.mean_item_latency_ms, 1),
            "mean_body_ms": round(run.mean_item_analysis_ms, 1),
            "cost_per_item_usd": round(agg[arm]["cost_per_item"], 6),
            "replicas_started": eng.instances_started,
            "terminated": eng.instances_terminated,
            "admission_defers": defers,
            "decisions": ";".join(
                f"{n}:{p.controller.decision_summary()}"
                for n, p in eng.platforms.items()),
        })

    s, q = agg["static"], agg["queue-aware"]
    # CI guards: the dynamic bound must actually engage and must win on
    # selection churn and cost per item (its headline metrics)
    assert rows[1]["admission_defers"] > 0, "queue-aware arm never deferred"
    assert q["started"] < s["started"], (
        f"queue-aware must reduce replica churn "
        f"({q['started']} vs {s['started']})")
    assert q["cost_per_item"] < s["cost_per_item"], (
        f"queue-aware must reduce cost per item "
        f"({q['cost_per_item']:.6f} vs {s['cost_per_item']:.6f})")
    headline = (
        f"cost_ratio={q['cost_per_item'] / s['cost_per_item']:.3f}"
        f"_replicas_started={s['started']}->{q['started']}"
        f"_terminated={s['terminated']}->{q['terminated']}"
        f"_latency_ratio={q['latency_ms'] / s['latency_ms']:.2f}"
    )
    return rows, headline


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer items/seeds")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: 4 items, short decodes")
    ap.add_argument("--load-aware", action="store_true",
                    help="load-model arm: concurrency-4 replicas, "
                         "load**0.6 slowdown, load-aware gate, 200+ items")
    ap.add_argument("--controllers", action="store_true",
                    help="admission-policy arms: static vs queue-aware "
                         "per-stage admission on the load-aware scenario")
    args = ap.parse_args()
    if args.controllers:
        rows, headline = admission_sweep(quick=args.quick, smoke=args.smoke)
        print(f"pipeline_admission_sweep,{headline}")
    elif args.load_aware:
        rows, headline = load_aware_sweep(smoke=args.smoke)
        print(f"pipeline_sweep_load_aware,{headline}")
    elif args.smoke:
        rows, headline, _, _ = pipeline_sweep(
            quick=True, n_items=4, seeds=(3,),
            spec=PipelineSpec(transcript_tokens=3, answer_tokens=4, max_pool=3),
        )
        print(f"pipeline_sweep,{headline}")
    else:
        rows, headline, _, _ = pipeline_sweep(quick=args.quick)
        print(f"pipeline_sweep,{headline}")
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
