"""Gated-vs-ungated sweep of the whisper→llama serving pipeline
(EXPERIMENTS.md §Pipeline sweep).

The paper's §V workflow argument on REAL model compute: both stages keep a
Minos-gated replica pool, the fast pools are re-used across every item, and
the sweep reports end-to-end item latency, body (compute) time, and cost
per item for each arm. ``--smoke`` runs a tiny config (CI entry-point
guard); model outputs are asserted identical across arms.

Usage: PYTHONPATH=src python benchmarks/pipeline_sweep.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.serving.pipeline import (
    PIPELINE_ARMS,
    PipelineSpec,
    build_asr_llm_pipeline,
    pipeline_arm_factory,
    pipeline_pricing,
)
from repro.sim.variation import VariationModel
from repro.sim.workflow_dag import WorkflowEngine, run_workflow_batch


def pipeline_sweep(quick: bool = False, *, n_items: int | None = None,
                   seeds: tuple[int, ...] | None = None,
                   spec: PipelineSpec | None = None):
    spec = spec or PipelineSpec()
    n_items = n_items if n_items is not None else (12 if quick else 30)
    seeds = seeds if seeds is not None else ((3,) if quick else (3, 4))
    vm = VariationModel(sigma=spec.speed_sigma)
    dag, backends = build_asr_llm_pipeline(spec, seed=0)  # weights shared by all arms

    rows = []
    agg: dict[str, dict[str, float]] = {}
    outputs: dict[str, list] = {}
    for arm in PIPELINE_ARMS:
        lat, body, cost, term = [], [], [], []
        for seed in seeds:
            eng = WorkflowEngine(dag, vm, pipeline_arm_factory(arm),
                                 pricing=pipeline_pricing(), seed=seed)
            run = run_workflow_batch(eng, n_items=n_items, inter_arrival_ms=400.0,
                                     payload_fn=lambda i: {"audio_id": i})
            run.items.sort(key=lambda it: it.item_id)
            if seed == seeds[0]:
                outputs[arm] = [it.stage_results["llm"].output for it in run.items]
            lat.append(run.mean_item_latency_ms)
            body.append(run.mean_item_analysis_ms)
            cost.append(run.cost.total / max(1, run.n_items))
            term.append(eng.instances_terminated)
        agg[arm] = {
            "latency_ms": float(np.mean(lat)),
            "body_ms": float(np.mean(body)),
            "cost_per_item": float(np.mean(cost)),
            "terminated": float(np.mean(term)),
        }
        rows.append({
            "arm": arm,
            "items": n_items,
            "mean_item_ms": round(agg[arm]["latency_ms"], 1),
            "mean_body_ms": round(agg[arm]["body_ms"], 1),
            "cost_per_item_usd": round(agg[arm]["cost_per_item"], 6),
            "terminated": round(agg[arm]["terminated"], 1),
        })

    # instance selection is performance-transparent: identical tokens per item
    for arm in PIPELINE_ARMS[1:]:
        for a, b in zip(outputs[PIPELINE_ARMS[0]], outputs[arm]):
            assert np.array_equal(a, b), "pipeline outputs must not depend on gating"

    base = agg["disabled"]
    body_gain = (base["body_ms"] - agg["fixed"]["body_ms"]) / base["body_ms"]
    lat_gain = (base["latency_ms"] - agg["fixed"]["latency_ms"]) / base["latency_ms"]
    cost_ratio = agg["fixed"]["cost_per_item"] / base["cost_per_item"]
    headline = (
        f"gated_body_gain={body_gain*100:.1f}%_latency_gain={lat_gain*100:.1f}%"
        f"_cost_ratio={cost_ratio:.2f}_outputs_identical=True"
    )
    return rows, headline


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer items/seeds")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: 4 items, short decodes")
    args = ap.parse_args()
    if args.smoke:
        rows, headline = pipeline_sweep(
            quick=True, n_items=4, seeds=(3,),
            spec=PipelineSpec(transcript_tokens=3, answer_tokens=4, max_pool=3),
        )
    else:
        rows, headline = pipeline_sweep(quick=args.quick)
    print(f"pipeline_sweep,{headline}")
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
