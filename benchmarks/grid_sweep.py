"""Pass-fraction × σ × platform × gate grid over the vectorized fast path
(EXPERIMENTS.md §Grid sweep; DESIGN.md §11).

The §II-A trade-off ("the optimal termination rate depends on the duration
of the workload, the performance variability of the platform, and the
relative time of the benchmark") is a *surface*, not a point — but the
event engine prices one arm at tens of milliseconds of Python, so
EXPERIMENTS.md could only ever report hand-picked slices of it. The jitted
``sim/vectorized.py`` scan runs the full grid (1,000+ arms × seeds) in one
XLA program; this sweep measures the surface AND the speedup:

* per (platform × gate × σ) row: the best pass fraction and its
  analysis-time improvement over the ungated baseline at the same σ —
  the pass-fraction × σ heatmap ridge;
* a wall-clock comparison against the event engine driven through the
  *same* scenario (single closed-loop stream, same spec/profile/gate;
  :func:`repro.sim.vectorized.run_event_chain`), reported as per-arm
  throughput (one arm = one seeded run of ``n_steps`` requests).

Timing lines go to **stderr** so two runs of ``--smoke`` produce
byte-identical stdout (the CI determinism diff); ``--smoke`` also asserts
the jit cache hits on a second arm-batch and a ≥20× measured speedup.

Usage: PYTHONPATH=src python benchmarks/grid_sweep.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import itertools
import math
import sys
import time

import numpy as np
from scipy import stats

from repro.core.policy import MinosPolicy
from repro.sim import FunctionSpec, PlatformProfile, VariationModel
from repro.sim.experiment import PAPER_PRICING
from repro.sim.platform import FaaSPlatform
from repro.sim.vectorized import (
    arm_from_spec,
    jit_stats,
    run_event_chain,
    simulate_arms,
    stack_arms,
)

# PAPER_SPEC shape with churn high enough that every arm observes a dense
# cold-probe stream — the grid estimates pass rates, so probes must flow
SPEC = FunctionSpec(
    name="weather-linreg-grid",
    prepare_ms=600.0,
    body_ms=1500.0,
    benchmark_ms=300.0,
    cold_start_ms=250.0,
    recycle_lifetime_ms=8_000.0,
    contention_rho=0.95,
    benchmark_noise=0.08,
)
THINK_MS = 500.0


def _profiles():
    import dataclasses
    # churny variants of the three platform presets (recycle as in SPEC,
    # paper pricing so costs are comparable across platforms)
    return [
        dataclasses.replace(p, recycle_lifetime_ms=SPEC.recycle_lifetime_ms,
                            pricing=PAPER_PRICING)
        for p in (PlatformProfile.gcf_gen1(), PlatformProfile.gcf_gen2(),
                  PlatformProfile.aws_lambda())
    ]


def analytic_threshold(pass_fraction: float, sigma: float) -> float:
    """f-quantile of the probe-duration distribution: probes are lognormal
    with log-std sqrt(σ² + observation-noise²) around log(benchmark_ms)."""
    spread = math.sqrt(sigma ** 2 + SPEC.benchmark_noise ** 2)
    return SPEC.benchmark_ms * math.exp(stats.norm.ppf(pass_fraction) * spread)


def build_grid(fracs, sigmas, profiles, gates):
    """One arm per (pass-fraction × σ × platform × gate) cell. Gate "off"
    arms ignore the pass fraction (they are the shared baseline of every
    fraction at that (platform, σ)), so they are built once per (σ,
    platform) and indexed separately."""
    arms, meta = [], []
    for prof, s in itertools.product(profiles, sigmas):
        vm = VariationModel(sigma=float(s))
        arms.append(arm_from_spec(SPEC, vm, profile=prof, gate="off",
                                  think_time_ms=THINK_MS))
        meta.append({"platform": prof.name, "sigma": float(s),
                     "gate": "off", "f": None})
        for f, gate in itertools.product(fracs, gates):
            arms.append(arm_from_spec(
                SPEC, vm, profile=prof, gate=gate,
                threshold=analytic_threshold(float(f), float(s)),
                pass_fraction=float(f), think_time_ms=THINK_MS))
            meta.append({"platform": prof.name, "sigma": float(s),
                         "gate": gate, "f": float(f)})
    return stack_arms(arms), meta


def _event_reference(n_requests: int, n_arms: int = 2,
                     repeats: int = 2) -> float:
    """Wall-clock seconds per event-engine arm on the same scenario (gen1,
    σ=0.15, fixed gate at f=0.4 — a mid-grid cell). Best-of-``repeats``:
    min-based timing reports the engine's capability, not scheduler noise,
    and biases the reported speedup DOWN (conservative)."""
    prof = _profiles()[0]
    vm = VariationModel(sigma=0.15)
    thr = analytic_threshold(0.4, 0.15)
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        for seed in range(n_arms):
            plat = FaaSPlatform(
                SPEC, vm, MinosPolicy(elysium_threshold=thr, max_retries=5),
                seed=seed, profile=prof)
            run_event_chain(plat, n_requests, THINK_MS)
        best = min(best, (time.perf_counter() - t0) / n_arms)
    return best


def grid_sweep(quick: bool = False, *, smoke: bool = False, seed: int = 0,
               report_timing: bool = True):
    """Returns (rows, headline, perf). ``perf`` carries the machine-readable
    numbers benchmarks/run.py persists to BENCH_substrate.json."""
    if smoke:
        fracs = np.linspace(0.2, 0.8, 4)
        sigmas = np.linspace(0.08, 0.2, 3)
        profiles = _profiles()[:1]
        gates = ("fixed",)
        n_steps, seeds = 200, range(seed, seed + 4)
    elif quick:
        fracs = np.linspace(0.1, 0.9, 8)
        sigmas = np.linspace(0.05, 0.25, 8)
        profiles = _profiles()[:2]
        gates = ("fixed", "adaptive")
        n_steps, seeds = 300, range(seed, seed + 4)
    else:
        fracs = np.linspace(0.06, 0.94, 23)
        sigmas = np.linspace(0.04, 0.26, 15)
        profiles = _profiles()
        gates = ("fixed",)
        n_steps, seeds = 400, range(seed, seed + 4)

    arms, meta = build_grid(fracs, sigmas, profiles, gates)
    n_arms = len(meta)
    t0 = time.perf_counter()
    res = simulate_arms(arms, seeds=seeds, n_steps=n_steps)
    t_first = time.perf_counter() - t0
    compiles_after_first = jit_stats["compiles"]
    t_cached = math.inf
    for _ in range(2):  # best-of-2, like the event reference
        t0 = time.perf_counter()
        res = simulate_arms(arms, seeds=seeds, n_steps=n_steps)
        t_cached = min(t_cached, time.perf_counter() - t0)
    recompiles_second = jit_stats["compiles"] - compiles_after_first
    lanes = n_arms * len(list(seeds))

    ev_per_arm = _event_reference(n_steps, n_arms=2 if smoke else 3)
    vec_per_lane = t_cached / lanes
    speedup = ev_per_arm / vec_per_lane
    events_per_sec = lanes * n_steps / t_cached

    mean_an = res.mean_over_seeds("mean_analysis_ms")
    pass_rate = res.mean_over_seeds("pass_rate")
    cost = res.mean_over_seeds("cost")

    # index the off-arm baseline of each (platform, σ)
    base = {(m["platform"], m["sigma"]): i
            for i, m in enumerate(meta) if m["gate"] == "off"}
    rows = []
    best_cell = (-math.inf, None)  # -inf: bm is set even if no cell beats
    # its baseline (a headline must never crash a completed sweep)
    for prof in profiles:
        for gate in gates:
            for s in sigmas:
                s = float(s)
                b = base[(prof.name, s)]
                cells = [(i, m) for i, m in enumerate(meta)
                         if m["platform"] == prof.name and m["gate"] == gate
                         and m["sigma"] == s]
                imps = [(1.0 - mean_an[i] / mean_an[b], i, m) for i, m in cells]
                best_imp, bi, bm = max(imps)
                if best_imp > best_cell[0]:
                    best_cell = (best_imp, bm)
                rows.append({
                    "platform": prof.name,
                    "gate": gate,
                    "sigma": round(s, 3),
                    "best_f": round(bm["f"], 3),
                    "best_improvement_pct": round(best_imp * 100, 2),
                    "pass_rate_at_best": round(float(pass_rate[bi]), 3),
                    "cost_delta_pct": round(
                        (cost[bi] / cost[b] - 1.0) * 100, 2),
                    "baseline_ms": round(float(mean_an[b]), 1),
                })

    perf = {
        "n_arms": n_arms,
        "n_lanes": lanes,
        "n_steps": n_steps,
        "wall_clock_s": round(t_cached, 4),
        "compile_s": round(t_first - t_cached, 4),
        "events_per_sec": round(events_per_sec, 1),
        "arms_per_sec": round(n_arms / t_cached, 2),
        "event_engine_per_arm_s": round(ev_per_arm, 5),
        "speedup_per_arm": round(speedup, 1),
        "jit_recompiles_second_batch": recompiles_second,
    }
    if report_timing:
        print(f"grid_sweep timing: arms={n_arms} lanes={lanes} "
              f"steps={n_steps} first={t_first:.2f}s cached={t_cached:.2f}s "
              f"events/s={events_per_sec:.0f} event_per_arm={ev_per_arm*1e3:.1f}ms "
              f"speedup={speedup:.0f}x recompiles={recompiles_second}",
              file=sys.stderr)

    bi, bm = best_cell
    headline = (
        f"arms={n_arms}_best={bm['platform']}_s{bm['sigma']:.2f}"
        f"_f{bm['f']:.2f}_imp={bi*100:.1f}%"
    )
    if not smoke:
        # timing numbers stay off --smoke stdout (CI two-run diff)
        headline += f"_speedup={speedup:.0f}x_arms_per_s={n_arms/t_cached:.0f}"
    return rows, headline, perf


def _event_reference_loaded(n_requests: int, n_vus: int, n_arms: int = 3,
                            repeats: int = 2) -> float:
    """Event-engine seconds per arm on the loaded scenario (gcf-gen2-loaded,
    concurrency 4, alpha 0.6, fixed gate at f=0.4, ``n_vus`` closed-loop
    streams) — the arms that were event-engine-only before the slot model."""
    import dataclasses
    prof = dataclasses.replace(PlatformProfile.gcf_gen2_loaded(),
                               recycle_lifetime_ms=SPEC.recycle_lifetime_ms,
                               pricing=PAPER_PRICING)
    vm = VariationModel(sigma=0.15)
    thr = analytic_threshold(0.4, 0.15)
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        for seed in range(n_arms):
            plat = FaaSPlatform(
                SPEC, vm, MinosPolicy(elysium_threshold=thr, max_retries=5),
                seed=seed, profile=prof)
            run_event_chain(plat, n_requests, THINK_MS, n_vus=n_vus)
        best = min(best, (time.perf_counter() - t0) / n_arms)
    return best


def loadaware_sweep(quick: bool = False, *, smoke: bool = False,
                    seed: int = 0, report_timing: bool = True):
    """Pass-fraction × alpha grid on gcf-gen2-loaded through the
    multi-stream scan (ISSUE 7: concurrency-4 ``load**alpha`` arms with the
    load-aware gate as first-class ``lax.scan`` arms — before the per-slot
    in-flight model these ran only on the event engine, ~25–65× slower).

    Four closed-loop streams share the concurrency-4 slot pool, so warm
    bodies pay the live ``(load+1)**alpha`` contention factor and the gate
    judges probes at pool occupancy. Rows report, per alpha, the best pass
    fraction and its improvement over the ungated baseline *at the same
    alpha* — under self-contention the gate's benefit also flows through
    occupancy (fewer slow instances → less queueing), which is exactly
    what the per-slot model must capture (parity:
    tests/test_multistream_vectorized.py). Returns (rows, headline, perf),
    the benchmarks/run.py contract."""
    import dataclasses
    n_vus = 4
    if smoke:
        fracs = np.linspace(0.2, 0.8, 6)
        alphas = (0.2, 0.5, 0.8)
        n_steps, seeds = 200, range(seed, seed + 4)
    elif quick:
        fracs = np.linspace(0.1, 0.9, 8)
        alphas = (0.2, 0.5, 0.8)
        n_steps, seeds = 300, range(seed, seed + 6)
    else:
        fracs = np.linspace(0.06, 0.94, 15)
        alphas = (0.0, 0.2, 0.4, 0.6, 0.8)
        n_steps, seeds = 400, range(seed, seed + 8)

    arms, meta = [], []
    for a in alphas:
        prof = dataclasses.replace(
            PlatformProfile.gcf_gen2_loaded(alpha=float(a)),
            recycle_lifetime_ms=SPEC.recycle_lifetime_ms,
            pricing=PAPER_PRICING)
        vm = VariationModel(sigma=0.15)
        arms.append(arm_from_spec(SPEC, vm, profile=prof, gate="off",
                                  think_time_ms=THINK_MS))
        meta.append({"alpha": float(a), "gate": "off", "f": None})
        for f in fracs:
            arms.append(arm_from_spec(
                SPEC, vm, profile=prof, gate="fixed",
                threshold=analytic_threshold(float(f), 0.15),
                pass_fraction=float(f), think_time_ms=THINK_MS))
            meta.append({"alpha": float(a), "gate": "fixed", "f": float(f)})
    stacked = stack_arms(arms)
    n_arms = len(meta)

    t0 = time.perf_counter()
    res = simulate_arms(stacked, seeds=seeds, n_steps=n_steps,
                        n_streams=n_vus)
    t_first = time.perf_counter() - t0
    compiles_after_first = jit_stats["compiles"]
    t_cached = math.inf
    for _ in range(2):
        t0 = time.perf_counter()
        res = simulate_arms(stacked, seeds=seeds, n_steps=n_steps,
                            n_streams=n_vus)
        t_cached = min(t_cached, time.perf_counter() - t0)
    recompiles_second = jit_stats["compiles"] - compiles_after_first
    lanes = n_arms * len(list(seeds))

    ev_per_arm = _event_reference_loaded(n_steps, n_vus,
                                         n_arms=2 if smoke else 3)
    vec_per_lane = t_cached / lanes
    speedup = ev_per_arm / vec_per_lane
    events_per_sec = lanes * n_steps / t_cached

    mean_an = res.mean_over_seeds("mean_analysis_ms")
    pass_rate = res.mean_over_seeds("pass_rate")
    base = {m["alpha"]: i for i, m in enumerate(meta) if m["gate"] == "off"}
    rows = []
    best_cell = (-math.inf, None)
    for a in alphas:
        a = float(a)
        b = base[a]
        cells = [(i, m) for i, m in enumerate(meta)
                 if m["alpha"] == a and m["gate"] == "fixed"]
        imps = [(1.0 - mean_an[i] / mean_an[b], i, m) for i, m in cells]
        best_imp, bi, bm = max(imps)
        if best_imp > best_cell[0]:
            best_cell = (best_imp, bm)
        rows.append({
            "alpha": round(a, 2),
            "best_f": round(bm["f"], 3),
            "best_improvement_pct": round(best_imp * 100, 2),
            "pass_rate_at_best": round(float(pass_rate[bi]), 3),
            "baseline_ms": round(float(mean_an[b]), 1),
        })

    perf = {
        "n_arms": n_arms,
        "n_lanes": lanes,
        "n_steps": n_steps,
        "n_streams": n_vus,
        "wall_clock_s": round(t_cached, 4),
        "compile_s": round(t_first - t_cached, 4),
        "events_per_sec": round(events_per_sec, 1),
        "arms_per_sec": round(n_arms / t_cached, 2),
        "event_engine_per_arm_s": round(ev_per_arm, 5),
        "speedup_per_arm": round(speedup, 1),
        "jit_recompiles_second_batch": recompiles_second,
    }
    if report_timing:
        print(f"loadaware_sweep timing: arms={n_arms} lanes={lanes} "
              f"steps={n_steps} vus={n_vus} first={t_first:.2f}s "
              f"cached={t_cached:.2f}s events/s={events_per_sec:.0f} "
              f"event_per_arm={ev_per_arm*1e3:.1f}ms "
              f"speedup={speedup:.0f}x recompiles={recompiles_second}",
              file=sys.stderr)
    bi, bm = best_cell
    headline = f"arms={n_arms}_best_alpha{bm['alpha']:.1f}" \
               f"_f{bm['f']:.2f}_imp={bi*100:.1f}%"
    if not smoke:
        headline += f"_speedup={speedup:.0f}x"
    return rows, headline, perf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller grid, 2 platforms, adaptive arms included")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI grid; asserts jit-cache hit and >=20x "
                         "speedup; deterministic stdout (timing on stderr)")
    ap.add_argument("--loadaware", action="store_true",
                    help="run the load-aware (concurrency-4 load**alpha) "
                         "grid instead of the single-stream grid")
    args = ap.parse_args()
    sweep = loadaware_sweep if args.loadaware else grid_sweep
    name = "loadaware_sweep" if args.loadaware else "grid_sweep"
    rows, headline, perf = sweep(quick=args.quick, smoke=args.smoke)
    if args.smoke:
        # CI guards: the second arm-batch must reuse the compiled program,
        # and the measured per-arm speedup must clear the smoke bar
        assert perf["jit_recompiles_second_batch"] == 0, \
            f"second batch recompiled: {perf}"
        assert perf["speedup_per_arm"] >= 20.0, \
            f"speedup {perf['speedup_per_arm']}x < 20x: {perf}"
        print(f"{name}_smoke_guards,jit_cache_hit=ok,speedup_bar=ok",
              file=sys.stderr)
    print(f"{name},{headline}")
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
