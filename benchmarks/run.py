"""Benchmark harness: one entry per paper figure/table + kernel micro +
roofline aggregation + the vectorized grid sweep. Prints
``name,us_per_call,derived`` CSV rows per the repo convention, then
detailed per-figure tables.

Every run also persists machine-readable timings to
``benchmarks/BENCH_substrate.json`` (per-sweep wall-clock, plus the grid
sweep's events/sec + arms/sec), so the repo carries a perf trajectory
across PRs; when a previous file exists a one-line delta is printed.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,...]
"""
import argparse
import json
import os
import sys
import time

_BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def _sanitized() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def _bench_json_path(quick: bool) -> str:
    """Quick runs use shorter windows, so their wall-clocks are not
    comparable to full runs — each mode keeps its own baseline file (the
    committed perf trajectory is the full one). REPRO_SANITIZE runs get a
    third file: their wall-clocks carry the sanitizer's checking overhead,
    and the delta against the matching plain file IS the overhead
    measurement (target <=2x, DESIGN.md §13)."""
    name = "BENCH_substrate.quick.json" if quick else "BENCH_substrate.json"
    if _sanitized():
        name = name.replace(".json", ".sanitize.json")
    return os.path.join(_BENCH_DIR, name)


def _print_sanitize_overhead(quick: bool, cur: dict) -> None:
    """Compare a sanitized run to the matching plain baseline file."""
    plain_name = ("BENCH_substrate.quick.json" if quick
                  else "BENCH_substrate.json")
    plain = _load_previous(os.path.join(_BENCH_DIR, plain_name))
    plain_r, cur_r = plain.get("results", {}), cur.get("results", {})
    common = [n for n in cur_r if n in plain_r
              and plain_r[n].get("wall_clock_s", 0) > 0]
    if not common:
        return
    base = sum(plain_r[n]["wall_clock_s"] for n in common)
    san = sum(cur_r[n]["wall_clock_s"] for n in common)
    print(f"SANITIZE overhead vs {plain_name} ({len(common)} sweeps): "
          f"{base:.1f}s->{san:.1f}s ({san / base:.2f}x)")


def _load_previous(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _print_delta(prev: dict, cur: dict) -> None:
    """One line comparing this run to the previous BENCH_substrate.json."""
    prev_r, cur_r = prev.get("results", {}), cur.get("results", {})
    common = [n for n in cur_r if n in prev_r]
    if not common:
        return
    old = sum(prev_r[n]["wall_clock_s"] for n in common)
    new = sum(cur_r[n]["wall_clock_s"] for n in common)
    parts = [f"total {old:.1f}s->{new:.1f}s ({(new - old) / old * 100:+.0f}%)"
             if old > 0 else f"total {new:.1f}s"]
    for sweep, short in (("grid_sweep", "grid"),
                         ("loadaware_sweep", "loadaware"),
                         ("vec_admission_sweep", "vec-admission")):
        g_old = prev_r.get(sweep, {}).get("events_per_sec")
        g_new = cur_r.get(sweep, {}).get("events_per_sec")
        if g_old and g_new:
            parts.append(f"{short} {g_old:.0f}->{g_new:.0f} events/s "
                         f"({(g_new - g_old) / g_old * 100:+.0f}%)")
        elif g_new:
            parts.append(f"{short} {g_new:.0f} events/s (new)")
    print(f"BENCH delta vs previous ({len(common)} sweeps): "
          + ", ".join(parts))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="shorter sim windows")
    ap.add_argument("--only", default="", help="comma-separated benchmark names")
    ap.add_argument("--no-bench-json", action="store_true",
                    help="skip writing benchmarks/BENCH_substrate.json")
    args = ap.parse_args()

    from . import (diurnal_sweep, fault_sweep, figs, fleet_sweep,
                   grid_sweep, kernels_micro, openloop_sweep,
                   pipeline_sweep, roofline_table, workflow_sweep)

    benches = {
        "workflow_sweep": workflow_sweep.workflow_sweep,
        "pipeline_sweep": pipeline_sweep.pipeline_sweep,
        "diurnal_sweep": diurnal_sweep.diurnal_sweep,
        # control-plane arms (DESIGN.md §10): rows carry a `decisions`
        # column naming which controller handled each decision point
        "diurnal_controllers": diurnal_sweep.controller_sweep,
        "pipeline_admission": pipeline_sweep.admission_sweep,
        # vectorized Monte-Carlo fast path (DESIGN.md §11)
        "grid_sweep": grid_sweep.grid_sweep,
        # n-streams-per-lane slot pool: concurrency-4 load**alpha arms on
        # the scan (ISSUE 7; DESIGN.md §11)
        "loadaware_sweep": grid_sweep.loadaware_sweep,
        # open-loop arrival traffic: rate × burstiness × gate (DESIGN.md §12)
        "openloop_sweep": openloop_sweep.openloop_sweep,
        # in-scan admission pipeline: defer/drop arms on the open scan
        "vec_admission_sweep": openloop_sweep.vec_admission_sweep,
        # fleet meta-scheduler: routing policies over heterogeneous
        # Minos-gated fleets on one clock (DESIGN.md §14)
        "fleet_sweep": fleet_sweep.fleet_sweep,
        # fault-injection ladder × recovery ladder × gate on/off: crash
        # misattribution + retry-storm questions (DESIGN.md §15)
        "fault_sweep": fault_sweep.fault_sweep,
        "fig4_regression_duration": figs.fig4_regression_duration,
        "fig5_successful_requests": figs.fig5_successful_requests,
        "fig6_cost_per_day": figs.fig6_cost_per_day,
        "fig7_cost_over_time": figs.fig7_cost_over_time,
        "ablation_pass_fraction": figs.ablation_pass_fraction,
        "ablation_stale_threshold": figs.ablation_stale_threshold,
        "ablation_online_controller": figs.ablation_online_controller,
        "kernel_micro": kernels_micro.kernel_micro,
        "roofline_table": roofline_table.roofline_table,
    }
    selected = [s for s in args.only.split(",") if s] or list(benches)
    unknown = [s for s in selected if s not in benches]
    if unknown:
        sys.exit(f"unknown benchmark(s): {', '.join(unknown)}; "
                 f"available: {', '.join(benches)}")

    print("name,us_per_call,derived")
    details = []
    bench_results = {}
    failures = 0
    for name in selected:
        fn = benches[name]
        t0 = time.perf_counter()
        try:
            rows, headline, *extra = fn(quick=args.quick)
            wall = time.perf_counter() - t0
            print(f"{name},{wall * 1e6:.0f},{headline}")
            details.append((name, rows))
            record = {"wall_clock_s": round(wall, 3), "headline": headline}
            if extra and isinstance(extra[0], dict):
                record.update(extra[0])  # grid_sweep perf numbers
            bench_results[name] = record
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},0,FAILED:{type(e).__name__}:{e}")
    for name, rows in details:
        print(f"\n== {name} ==")
        if rows:
            cols = list(rows[0].keys())
            print(",".join(cols))
            for r in rows:
                print(",".join(str(r[c]) for c in cols))

    if bench_results and not args.no_bench_json:
        path = _bench_json_path(args.quick)
        prev = _load_previous(path)
        cur = {
            "schema": 1,
            "quick": bool(args.quick),
            "sanitized": _sanitized(),
            "results": bench_results,
        }
        _print_delta(prev, cur)
        if _sanitized():
            _print_sanitize_overhead(args.quick, cur)
        # merge: a --only (or partially failed) run must not wipe the
        # baselines of sweeps it did not execute
        merged = dict(prev.get("results", {}))
        merged.update(bench_results)
        cur["results"] = merged
        with open(path, "w") as f:
            json.dump(cur, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
