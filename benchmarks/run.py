"""Benchmark harness: one entry per paper figure/table + kernel micro +
roofline aggregation. Prints ``name,us_per_call,derived`` CSV rows per the
repo convention, then detailed per-figure tables.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,...]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="shorter sim windows")
    ap.add_argument("--only", default="", help="comma-separated benchmark names")
    args = ap.parse_args()

    from . import (diurnal_sweep, figs, kernels_micro, pipeline_sweep,
                   roofline_table, workflow_sweep)

    benches = {
        "workflow_sweep": workflow_sweep.workflow_sweep,
        "pipeline_sweep": pipeline_sweep.pipeline_sweep,
        "diurnal_sweep": diurnal_sweep.diurnal_sweep,
        # control-plane arms (DESIGN.md §10): rows carry a `decisions`
        # column naming which controller handled each decision point
        "diurnal_controllers": diurnal_sweep.controller_sweep,
        "pipeline_admission": pipeline_sweep.admission_sweep,
        "fig4_regression_duration": figs.fig4_regression_duration,
        "fig5_successful_requests": figs.fig5_successful_requests,
        "fig6_cost_per_day": figs.fig6_cost_per_day,
        "fig7_cost_over_time": figs.fig7_cost_over_time,
        "ablation_pass_fraction": figs.ablation_pass_fraction,
        "ablation_stale_threshold": figs.ablation_stale_threshold,
        "ablation_online_controller": figs.ablation_online_controller,
        "kernel_micro": kernels_micro.kernel_micro,
        "roofline_table": roofline_table.roofline_table,
    }
    selected = [s for s in args.only.split(",") if s] or list(benches)
    unknown = [s for s in selected if s not in benches]
    if unknown:
        sys.exit(f"unknown benchmark(s): {', '.join(unknown)}; "
                 f"available: {', '.join(benches)}")

    print("name,us_per_call,derived")
    details = []
    failures = 0
    for name in selected:
        fn = benches[name]
        t0 = time.perf_counter()
        try:
            rows, headline, *_ = fn(quick=args.quick)
            us = (time.perf_counter() - t0) * 1e6
            print(f"{name},{us:.0f},{headline}")
            details.append((name, rows))
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},0,FAILED:{type(e).__name__}:{e}")
    for name, rows in details:
        print(f"\n== {name} ==")
        if rows:
            cols = list(rows[0].keys())
            print(",".join(cols))
            for r in rows:
                print(",".join(str(r[c]) for c in cols))
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
