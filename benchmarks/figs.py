"""Paper-figure reproductions (one function per figure) on the calibrated
simulator. Each returns (rows, headline) where rows are CSV-able dicts."""
from __future__ import annotations

import time

import numpy as np

from repro.sim import run_week


_WEEK_CACHE: dict[tuple, object] = {}


def _week(seed=0, quick=False):
    key = (seed, quick)
    if key not in _WEEK_CACHE:
        dur = (10 if quick else 30) * 60 * 1000.0
        _WEEK_CACHE[key] = run_week(seed=seed, duration_ms=dur)
    return _WEEK_CACHE[key]


def fig4_regression_duration(quick=False):
    """Fig 4: average linear-regression (analysis) duration per day."""
    wk = _week(quick=quick)
    rows = [
        {
            "day": d.day,
            "baseline_ms": round(d.baseline.mean_analysis_ms, 1),
            "minos_ms": round(d.minos.mean_analysis_ms, 1),
            "improvement_pct": round(d.analysis_improvement * 100, 2),
        }
        for d in wk.days
    ]
    return rows, f"avg_improvement={wk.overall_analysis_improvement*100:.1f}%"


def fig5_successful_requests(quick=False):
    """Fig 5: successful requests per day per arm."""
    wk = _week(quick=quick)
    rows = [
        {
            "day": d.day,
            "baseline": d.baseline.n_successful,
            "minos": d.minos.n_successful,
            "delta_pct": round(d.successful_requests_delta * 100, 2),
        }
        for d in wk.days
    ]
    return rows, f"overall_delta={wk.overall_successful_delta*100:+.1f}%"


def fig6_cost_per_day(quick=False):
    """Fig 6: average total cost per million successful requests per day."""
    wk = _week(quick=quick)
    rows = [
        {
            "day": d.day,
            "baseline_usd_per_m": round(d.baseline.cost_per_million, 3),
            "minos_usd_per_m": round(d.minos.cost_per_million, 3),
            "saving_pct": round(d.cost_saving * 100, 2),
        }
        for d in wk.days
    ]
    return rows, f"overall_saving={wk.overall_cost_saving*100:+.2f}%"


def fig7_cost_over_time(quick=False):
    """Fig 7: running cost per successful request over elapsed time,
    averaged over the week; crossover + cheaper-fraction."""
    wk = _week(quick=quick)
    M = np.mean([d.timeline_minos[1] for d in wk.days], axis=0)
    B = np.mean([d.timeline_baseline[1] for d in wk.days], axis=0)
    t = wk.days[0].timeline_minos[0]
    cheaper = M < B
    idx = np.where(~cheaper)[0]
    last_not_cheaper_s = float(t[idx[-1]] / 1000) if len(idx) else 0.0
    frac = float(np.mean(cheaper))
    early = float(np.mean(M[t < 200e3] / B[t < 200e3])) if (t < 200e3).any() else 1.0
    rows = [
        {"metric": "cheaper_fraction", "value": round(frac, 3)},
        {"metric": "last_crossover_s", "value": round(last_not_cheaper_s, 1)},
        {"metric": "early_cost_ratio_first200s", "value": round(early, 3)},
    ]
    return rows, f"cheaper_{frac*100:.0f}%_of_window"


def ablation_pass_fraction(quick=True):
    """§II-A trade-off: sweep the elysium pass fraction; cost is U-shaped
    (terminate too much -> waste; too little -> slow pool)."""
    from repro.core.policy import MinosPolicy
    from repro.sim import PAPER_PRICING, PAPER_SPEC, FaaSPlatform, run_closed_loop
    from repro.sim.variation import VariationModel

    vm = VariationModel(sigma=0.15)
    rows = []
    dur = (5 if quick else 15) * 60 * 1000.0
    for pf in (0.1, 0.2, 0.4, 0.6, 0.8, 1.0):
        thr = (
            PAPER_SPEC.benchmark_ms / vm.speed_quantile(1.0 - pf)
            if pf < 1.0
            else float("inf")
        )
        pol = MinosPolicy(elysium_threshold=thr, max_retries=5, enabled=pf < 1.0)
        plat = FaaSPlatform(PAPER_SPEC, vm, pol, PAPER_PRICING, seed=11)
        res = run_closed_loop(plat, n_vus=10, duration_ms=dur)
        rows.append(
            {
                "pass_fraction": pf,
                "cost_per_m": round(plat.cost.cost_per_million_successful(), 3),
                "mean_analysis_ms": round(
                    float(np.mean([r.analysis_ms for r in res])), 1
                ),
                "terminated": plat.instances_terminated,
            }
        )
    best = min(rows, key=lambda r: r["cost_per_m"])
    return rows, f"optimal_pass_fraction={best['pass_fraction']}"


def ablation_online_controller(quick=True):
    """§IV future work, implemented: the OnlineElysiumController (P² +
    Welford + EMA republish) vs a stale pre-tested threshold under a 25 %
    mid-experiment platform slowdown."""
    import dataclasses

    from repro.core import MinosPolicy, OnlineElysiumController
    from repro.sim import PAPER_PRICING, PAPER_SPEC, FaaSPlatform, run_closed_loop
    from repro.sim.variation import VariationModel

    dur = (7 if quick else 15) * 60 * 1000.0
    vm0 = VariationModel(sigma=0.15)
    thr = PAPER_SPEC.benchmark_ms / vm0.speed_quantile(0.6)
    rows = []
    for name, online in (("stale_pretest", False), ("online_p2", True)):
        ctrl = (
            OnlineElysiumController(pass_fraction=0.4, republish_every=8,
                                    smoothing_alpha=0.5, initial_threshold=thr)
            if online else None
        )
        succ, analysis, cost_total, term = 0, [], 0.0, 0
        for phase, day_factor in enumerate((1.0, 0.75)):  # 25% slowdown
            vm = VariationModel(sigma=0.15, day_factor=day_factor)
            pol = MinosPolicy(
                elysium_threshold=(ctrl.threshold if ctrl else thr), max_retries=5)
            plat = FaaSPlatform(PAPER_SPEC, vm, pol, PAPER_PRICING,
                                seed=17 + phase, online_controller=ctrl)
            res = run_closed_loop(plat, n_vus=10, duration_ms=dur)
            succ += len(res)
            analysis += [r.analysis_ms for r in res]
            cost_total += plat.cost.total
            term += plat.instances_terminated
        rows.append({
            "protocol": name,
            "successful": succ,
            "mean_analysis_ms": round(float(np.mean(analysis)), 1),
            "cost_per_m": round(cost_total / succ * 1e6, 3),
            "terminated": term,
        })
    saving = 1 - rows[1]["cost_per_m"] / rows[0]["cost_per_m"]
    return rows, f"online_saves_{saving*100:.1f}%_under_drift"


def ablation_stale_threshold(quick=True):
    """§IV motivation: one-shot pre-tested threshold vs per-day re-pretest."""
    dur = (10 if quick else 30) * 60 * 1000.0
    fresh = run_week(seed=3, duration_ms=dur, stale_threshold=False)
    stale = run_week(seed=3, duration_ms=dur, stale_threshold=True)
    rows = [
        {
            "protocol": "per_day_pretest",
            "cost_saving_pct": round(fresh.overall_cost_saving * 100, 2),
            "analysis_improvement_pct": round(
                fresh.overall_analysis_improvement * 100, 2),
        },
        {
            "protocol": "stale_week_threshold",
            "cost_saving_pct": round(stale.overall_cost_saving * 100, 2),
            "analysis_improvement_pct": round(
                stale.overall_analysis_improvement * 100, 2),
        },
    ]
    return rows, "online_recalibration_motivated"
