"""Fault-injection sweep: fault-rate ladder × recovery ladder × gate
on/off over a two-fleet Minos deployment (EXPERIMENTS.md §Fault sweep;
DESIGN.md §15).

Two robustness questions the fault substrate exists to answer:

* **Crash-vs-slow misattribution** — does the Minos gate misread a
  crash-prone fleet as a *slow* one? Injected faults are
  speed-independent by construction (the FaultPlan draws its own RNG
  stream; fault deaths are logged in ``fault_counts``, never in the
  gate's ``instances_terminated``), so the gate's termination counter
  under faults vs fault-free is the misattribution measurement: if the
  gate kills more instances when crashes rise, it is punishing speed
  for reliability's sins.
* **Retry storms** — engine-level fault retries re-enter the same queue
  the gate's probation retries use, incrementing ``retry_count`` toward
  the gate's forced-pass emergency exit. At high fault rates the gate
  is progressively bypassed; the sweep reports requeues and mean
  retries per completed request so the erosion is visible, and compares
  the gate's latency cut (gate-on vs gate-off) at every fault level.

Fleet 0 (gen1) takes the full injected fault rate plus an outage window
in the non-smoke modes; fleet 1 (gen2) takes 20% of it — the asymmetry
gives the circuit breaker something to discriminate. Recovery ladder:
``none`` (naive unbounded requeue), ``retry`` (capped attempts, backoff
with decorrelated jitter, per-request timeout, dead-letter), ``+breaker``
(per-fleet circuit breaker with failover), ``+shed`` (breaker plus
QoS-priority load shedding while degraded: bronze sheds first).

Timing goes to **stderr**; two ``--smoke`` runs produce byte-identical
stdout (the CI determinism diff). Event-driven control flow — no jitted
leg to guard.

Usage: PYTHONPATH=src python benchmarks/fault_sweep.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import sys
import time

import numpy as np
from scipy import stats

from repro.core.policy import MinosPolicy
from repro.faults import FaultPlan, FaultWindow, RecoveryPolicy
from repro.fleet import (
    BreakerConfig,
    FleetRouter,
    FleetSpec,
    RandomRoutingPolicy,
    run_fleet_open_loop,
)
from repro.sim import FunctionSpec, PlatformProfile, PoissonProcess, VariationModel
from repro.sim.arrivals import QoSClass
from repro.sim.metrics import FleetSummary

PASS_FRACTION = 0.4
BODY_MS = 1200.0
QOS = (QoSClass("gold", weight=2.0, priority=1, slo_ms=8 * BODY_MS),
       QoSClass("bronze", weight=1.0, priority=0, slo_ms=16 * BODY_MS))
QOS_PRIORITIES = {"gold": 1, "bronze": 0}
RECOVERY = RecoveryPolicy(timeout_ms=24 * BODY_MS, max_attempts=4,
                          backoff_base_ms=50.0, backoff_cap_ms=2_000.0)
BREAKER = BreakerConfig(window=16, failure_threshold=0.5, min_samples=5,
                        open_ms=10_000.0, trial_requests=3)


def _spec(rho: float = 0.3) -> FunctionSpec:
    return FunctionSpec(
        name="weather-linreg-faults",
        prepare_ms=300.0,
        body_ms=BODY_MS,
        benchmark_ms=300.0,
        contention_rho=rho,
        benchmark_noise=0.08,
    )


def _threshold(vm: VariationModel, spec: FunctionSpec) -> float:
    sigma_tot = math.sqrt(vm.sigma ** 2 + spec.benchmark_noise ** 2)
    return spec.benchmark_ms * math.exp(
        stats.norm.ppf(PASS_FRACTION) * sigma_tot)


def _gate(vm: VariationModel, spec: FunctionSpec, enabled: bool):
    if not enabled:
        return MinosPolicy(elysium_threshold=float("inf"), enabled=False)
    return MinosPolicy(elysium_threshold=_threshold(vm, spec), max_retries=5)


def _plan_factory(crash: float, *, scale: float, outage: bool):
    """Per-fleet FaultPlan factory: crash sets the level, satellites
    (cold-fail / probe-timeout / lost completion / throttle) scale with
    it. scale<1 models the healthier fleet; crash=0 → plan=None so the
    fault-free column runs the bit-identical no-plan path."""
    if crash <= 0.0:
        return None
    c = crash * scale
    windows = (FaultWindow(start_ms=40_000.0, end_ms=55_000.0,
                           kind="outage"),) if outage else ()

    def factory(seed: int) -> FaultPlan:
        return FaultPlan(seed=seed, crash_rate=c, cold_fail_rate=c / 2,
                         probe_timeout_rate=c / 2,
                         probe_timeout_ms=4 * BODY_MS,
                         lost_completion_rate=c / 4, throttle_rate=c / 8,
                         windows=windows)
    return factory


def _fleets(crash: float, *, gate_on: bool, recovery, outage: bool):
    spec = _spec()
    rows = [
        ("gen1", PlatformProfile.gcf_gen1(),
         VariationModel(sigma=0.30), 4, 1.0, outage),
        ("gen2", PlatformProfile.gcf_gen2(),
         VariationModel(sigma=0.10, day_factor=1.15), 1, 0.2, False),
    ]
    fleets = []
    for name, prof, vm, cap, scale, out in rows:
        knobs = dataclasses.replace(prof.knobs(), max_instances=cap)
        fleets.append(FleetSpec(
            name=name, spec=spec, variation=vm, profile=prof, knobs=knobs,
            policy=_gate(vm, spec, gate_on),
            fault_plan_factory=_plan_factory(crash, scale=scale, outage=out),
            recovery=recovery))
    return fleets


#: recovery ladder: (label, recovery, breaker, shed)
ARMS = (
    ("none", None, None, False),
    ("retry", RECOVERY, None, False),
    ("retry+breaker", RECOVERY, BREAKER, False),
    ("retry+breaker+shed", RECOVERY, BREAKER, True),
)


def _run_cell(crash, arm, gate_on, seeds, rate, duration_ms, outage):
    label, recovery, breaker, shed = arm
    summaries, extras = [], []
    for seed in seeds:
        fleets = _fleets(crash, gate_on=gate_on, recovery=recovery,
                         outage=outage)
        router = FleetRouter(
            fleets, RandomRoutingPolicy(), seed=seed,
            breaker=breaker, shed_when_degraded=shed,
            qos_priorities=QOS_PRIORITIES if shed else None)
        run = run_fleet_open_loop(
            router, PoissonProcess(rate),
            rng=np.random.RandomState(23_000 + seed),
            duration_ms=duration_ms, qos_classes=QOS,
            drain_limit_ms=180_000.0)
        router.check_conservation()  # every arm, not only under the env gate
        summaries.append(FleetSummary.from_run(label, router, run,
                                               qos_classes=QOS))
        extras.append({
            "gate_terms": sum(e.instances_terminated
                              for e in router.engines),
            "fault_deaths": sum(sum(e.fault_counts.values())
                                for e in router.engines),
            "requeues": sum(e.queue.total_requeued
                            for e in router.engines),
            "retries": (float(np.mean([r.retries for r in run.results]))
                        if run.results else 0.0),
        })
    return summaries, extras


def _pool(summaries, field) -> float:
    return float(np.mean([getattr(s, field) for s in summaries]))


def _gold_slo(summaries) -> float:
    vals = []
    for s in summaries:
        for row in s.slo_attainment:
            if row["qos"] == "gold" and row["n_completed"] > 0:
                vals.append(row["attainment"])
    return float(np.mean(vals)) if vals else float("nan")


def _row(crash, label, gate_on, summaries, extras):
    return {
        "crash_rate": crash,
        "recovery": label,
        "gate": "on" if gate_on else "off",
        "mean_ms": round(_pool(summaries, "mean_latency_ms"), 1),
        "p95_ms": round(_pool(summaries, "p95_latency_ms"), 1),
        "drop_pct": round(100 * _pool(summaries, "drop_rate"), 2),
        "dead": int(round(_pool(summaries, "n_dead_lettered"))),
        "shed": int(round(_pool(summaries, "n_shed"))),
        "breaker_opens": int(round(np.mean(
            [sum(s.breaker_opens) for s in summaries]))),
        "cost_per_1k": round(_pool(summaries, "cost_per_1k"), 4),
        "gate_terms": int(round(np.mean([e["gate_terms"] for e in extras]))),
        "fault_deaths": int(round(np.mean(
            [e["fault_deaths"] for e in extras]))),
        "requeues": int(round(np.mean([e["requeues"] for e in extras]))),
        "mean_retries": round(float(np.mean(
            [e["retries"] for e in extras])), 3),
        "gold_slo_pct": round(100 * _gold_slo(summaries), 1),
    }


def fault_sweep(quick: bool = False, *, smoke: bool = False,
                report_timing: bool = True):
    """Returns (rows, headline, perf) — the benchmarks/run.py contract."""
    if smoke:
        crashes = (0.0, 0.15)
        arms = (ARMS[1], ARMS[2])
        seeds = range(1)
        rate = 2.0
        duration_ms = 45_000.0
        outage = False
    elif quick:
        crashes = (0.0, 0.15)
        arms = ARMS
        seeds = range(2)
        rate = 2.0
        duration_ms = 90_000.0
        outage = True
    else:
        crashes = (0.0, 0.05, 0.15)
        arms = ARMS
        seeds = range(3)
        rate = 2.5
        duration_ms = 150_000.0
        outage = True

    t_sweep = time.perf_counter()
    rows = []
    cells = {}
    for crash in crashes:
        for arm in arms:
            for gate_on in (True, False):
                summaries, extras = _run_cell(
                    crash, arm, gate_on, seeds, rate, duration_ms, outage)
                cells[(crash, arm[0], gate_on)] = summaries
                rows.append(_row(crash, arm[0], gate_on, summaries, extras))
    t_event = time.perf_counter() - t_sweep
    n_requests = sum(s.n_arrived for ss in cells.values() for s in ss)

    # headline: the gate's latency cut with and without faults, under the
    # strongest recovery arm present — does injected failure erase (or
    # invert) the speedup the gate exists to deliver?
    best = arms[-1][0]
    top = max(crashes)

    def cut(crash):
        on = _pool(cells[(crash, best, True)], "mean_latency_ms")
        off = _pool(cells[(crash, best, False)], "mean_latency_ms")
        return (1.0 - on / off) * 100 if off else 0.0

    headline = (f"cells={len(rows)}_{best}_gate_cut"
                f"_f0={cut(crashes[0]):.0f}%_f{top:g}={cut(top):.0f}%")
    perf = {
        "n_cells": len(rows),
        "n_requests": n_requests,
        "event_wall_clock_s": round(t_event, 3),
        "event_arrivals_per_sec": round(n_requests / max(t_event, 1e-9), 1),
    }
    if report_timing:
        print(f"fault_sweep timing: cells={len(rows)} "
              f"requests={n_requests} event={t_event:.2f}s "
              f"({perf['event_arrivals_per_sec']:.0f} arrivals/s)",
              file=sys.stderr)
    return rows, headline, perf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 fault levels, 2 seeds, shorter windows")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI cell set; deterministic stdout "
                         "(timing on stderr)")
    args = ap.parse_args()
    rows, headline, _perf = fault_sweep(quick=args.quick, smoke=args.smoke)
    if args.smoke:
        print("fault_sweep_smoke_guards,conservation=ok", file=sys.stderr)
    print(f"fault_sweep,{headline}")
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
