"""Workflow-length × platform × arm sweep (EXPERIMENTS.md §Workflow sweep).

The paper's §V scaling claim, quantified: Minos end-to-end speedup grows
with workflow length because the CPU-bound (pool-served) share of an item's
latency grows while fixed overheads (network-bound extract, cold starts,
selection waste) amortize. Three arms per cell:

* ``disabled`` — baseline, no gate;
* ``fixed``    — pre-tested elysium threshold per stage (§III-A protocol);
* ``adaptive`` — online threshold (§IV), NO pre-test phase at all.

Speedup is the relative reduction of mean end-to-end item latency vs the
same platform's ``disabled`` arm, averaged over seeds.

Usage: PYTHONPATH=src python benchmarks/workflow_sweep.py [--quick|--smoke]
(--smoke: 1-/3-stage chains on gcf-gen1 only, one seed, 3-min windows —
the CI entry-point guard; the full sweep is the EXPERIMENTS.md protocol.)
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.sim import (
    ARMS,
    PlatformProfile,
    VariationModel,
    WorkflowEngine,
    WorkflowSummary,
    etl_chain,
    improvement,
    run_workflow_closed_loop,
    workflow_arm_factory,
)

STAGE_COUNTS = (1, 3, 5, 7)
SWEEP_SIGMA = 0.18


def _profiles():
    return {
        "gcf-gen1": PlatformProfile.gcf_gen1(),
        "gcf-gen2": PlatformProfile.gcf_gen2(),
        "lambda": PlatformProfile.aws_lambda(),
    }


def workflow_sweep(quick=False, *, smoke=False):
    if smoke:
        seeds, duration_ms = (42,), 3 * 60 * 1000.0
        stage_counts, profiles = (1, 3), {"gcf-gen1": PlatformProfile.gcf_gen1()}
    else:
        seeds = (42, 43, 44) if quick else (42, 43, 44, 45, 46)
        duration_ms = (8 if quick else 15) * 60 * 1000.0
        stage_counts, profiles = STAGE_COUNTS, _profiles()
    vm = VariationModel(sigma=SWEEP_SIGMA)

    rows = []
    speedups: dict[tuple[str, int, str], float] = {}
    for prof_name, prof in profiles.items():
        for n in stage_counts:
            dag = etl_chain(n)
            per_arm: dict[str, list[WorkflowSummary]] = {a: [] for a in ARMS}
            for seed in seeds:
                for arm in ARMS:
                    eng = WorkflowEngine(
                        dag, vm, workflow_arm_factory(arm, vm, pricing=prof.pricing),
                        profile=prof, seed=seed,
                    )
                    run = run_workflow_closed_loop(
                        eng, n_vus=10, duration_ms=duration_ms)
                    per_arm[arm].append(WorkflowSummary.from_run(arm, run))
            base_lat = float(np.mean(
                [s.mean_item_latency_ms for s in per_arm["disabled"]]))
            for arm in ARMS:
                lat = float(np.mean([s.mean_item_latency_ms for s in per_arm[arm]]))
                cost = float(np.mean([s.cost_per_million_items for s in per_arm[arm]]))
                term = float(np.mean([s.n_terminated for s in per_arm[arm]]))
                sp = improvement(base_lat, lat)
                speedups[(prof_name, n, arm)] = sp
                rows.append({
                    "profile": prof_name,
                    "stages": n,
                    "arm": arm,
                    "items": int(np.mean([s.n_items for s in per_arm[arm]])),
                    "mean_item_ms": round(lat, 1),
                    "speedup_pct": round(sp * 100, 2),
                    "cost_per_m_items": round(cost, 2),
                    "terminated": round(term, 1),
                })

    gen1 = [speedups[("gcf-gen1", n, "fixed")] for n in stage_counts]
    monotone = all(b > a for a, b in zip(gen1, gen1[1:]))
    # adaptive-vs-pretested convergence, averaged over workflow lengths —
    # per-length ratios are dominated by seed noise (EXPERIMENTS.md
    # §Workflow sweep); quick mode under-converges (short windows leave
    # the warm-up's unselected instances in the pools)
    mean_fixed = float(np.mean(gen1))
    mean_adaptive = float(np.mean(
        [speedups[("gcf-gen1", n, "adaptive")] for n in stage_counts]))
    ratio = mean_adaptive / mean_fixed if mean_fixed > 0 else float("nan")
    headline = (
        f"gen1_fixed_speedups={'/'.join(f'{s*100:.1f}%' for s in gen1)}"
        f"_monotone={monotone}_adaptive_vs_pretest_ratio={ratio:.2f}"
    )
    return rows, headline


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3 seeds, 8-min windows")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: gen1 only, 1-/3-stage, one seed")
    args = ap.parse_args()
    rows, headline = workflow_sweep(quick=args.quick, smoke=args.smoke)
    print(f"workflow_sweep,{headline}")
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
