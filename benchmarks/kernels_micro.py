"""Kernel microbenchmarks: wall time per call (CPU interpret mode — the
numbers validate plumbing + give the ref-vs-kernel overhead picture; real
TPU numbers come from the roofline analysis of the compiled HLO)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # warm up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def kernel_micro(quick=True):
    rows = []
    rs = np.random.RandomState(0)
    a = jnp.asarray(rs.randn(256, 512), jnp.float32)
    b = jnp.asarray(rs.randn(512, 256), jnp.float32)
    rows.append({"kernel": "matmul_probe", "us_per_call": round(_time(ops.matmul, a, b), 1),
                 "ref_us": round(_time(lambda x, y: ref.matmul_ref(x, y), a, b), 1)})
    q = jnp.asarray(rs.randn(1, 4, 256, 64), jnp.float32)
    k = jnp.asarray(rs.randn(1, 2, 256, 64), jnp.float32)
    v = jnp.asarray(rs.randn(1, 2, 256, 64), jnp.float32)
    rows.append({
        "kernel": "flash_attention",
        "us_per_call": round(_time(lambda *x: ops.flash_attention(*x), q, k, v), 1),
        "ref_us": round(_time(lambda *x: ref.attention_ref(*x), q, k, v), 1),
    })
    q1 = jnp.asarray(rs.randn(2, 4, 1, 64), jnp.float32)
    kc = jnp.asarray(rs.randn(2, 2, 512, 64), jnp.float32)
    vc = jnp.asarray(rs.randn(2, 2, 512, 64), jnp.float32)
    ln = jnp.array([512, 300], jnp.int32)
    rows.append({
        "kernel": "decode_attention",
        "us_per_call": round(_time(lambda *x: ops.decode_attention(*x), q1, kc, vc, ln), 1),
        "ref_us": round(_time(lambda *x: ref.decode_attention_ref(*x), q1, kc, vc, ln), 1),
    })
    return rows, "interpret_mode"
