"""Open-loop traffic sweep: rate ladder × burstiness × gate arms
(EXPERIMENTS.md §Open-loop sweep; DESIGN.md §12).

Every sweep before PR 6 was closed-loop — the next request fired on
completion, so the system could never be offered more load than it
finishes. This sweep drives the event engine with *open-loop* arrivals
(sim/arrivals.py) against a capped instance supply and maps what the
paper's gate does to tail latency, loss, and cost when traffic, not the
simulator, sets the pace:

* a **rate ladder** (ρ from comfortable to past saturation) per process
  shape: Poisson, MMPP on/off bursts (same stationary rate — burstiness
  isolated from mean load), and a diurnal rate curve;
* **gate arms**: baseline (off), the fixed Minos gate, and the gate with
  queue-aware admission stacked on top (defer instead of drop);
* per cell: completed-only P50/P95/P99, the honest ``wait_p99`` (censored
  waits folded in — metrics.OpenLoopSummary), drop/defer rates, and cost
  per 1k completed.

A vectorized leg runs the Poisson cells through the jitted open-loop scan
(``simulate_open_arms``) and reports per-lane throughput + the speedup
over the event engine on the same scenario; ``--smoke`` asserts the
second vec batch reuses the compiled program (zero recompiles).

Timing goes to **stderr** so two ``--smoke`` runs produce byte-identical
stdout (the CI determinism diff).

Usage: PYTHONPATH=src python benchmarks/openloop_sweep.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import sys
import time

import numpy as np
from scipy import stats

from repro.core.control import (
    ClassicMinosController,
    QueueAwareAdmissionController,
)
from repro.core.policy import MinosPolicy
from repro.sim import (
    DiurnalPoissonProcess,
    FaaSPlatform,
    FunctionSpec,
    MMPPProcess,
    PlatformProfile,
    PoissonProcess,
    VariationModel,
    run_open_loop,
)
from repro.sim.experiment import PAPER_PRICING
from repro.sim.metrics import OpenLoopSummary
from repro.sim.vectorized import (
    arm_from_spec,
    jit_stats,
    simulate_open_arms,
    stack_arms,
)

# PAPER_SPEC shape; churny recycle keeps the gate's probe stream dense
SPEC = FunctionSpec(
    name="weather-linreg-open",
    prepare_ms=600.0,
    body_ms=1500.0,
    benchmark_ms=300.0,
    cold_start_ms=250.0,
    recycle_lifetime_ms=8_000.0,
    contention_rho=0.95,
    benchmark_noise=0.08,
)
VM = VariationModel(sigma=0.15)
PASS_FRACTION = 0.4
N_SERVERS = 4  # the autoscaling supply cap (SubstrateKnobs.max_instances)
GATE_ARMS = ("off", "fixed", "fixed+admit")

THRESHOLD = SPEC.benchmark_ms * math.exp(
    stats.norm.ppf(PASS_FRACTION)
    * math.sqrt(VM.sigma ** 2 + SPEC.benchmark_noise ** 2))


def _profiles():
    return [
        dataclasses.replace(p, recycle_lifetime_ms=SPEC.recycle_lifetime_ms,
                            pricing=PAPER_PRICING)
        for p in (PlatformProfile.gcf_gen1(), PlatformProfile.aws_lambda())
    ]


def _processes(rate_per_s: float, duration_ms: float):
    """Three shapes at the SAME stationary rate: mean load is held fixed,
    so any row-to-row difference is the *shape* of the traffic. The MMPP
    splits r into base r/2 + bursts at 3r (on 5 s / off 20 s → stationary
    0.8·r/2 + 0.2·3r = r); the diurnal curve runs one full period over
    the window."""
    return [
        PoissonProcess(rate_per_s),
        MMPPProcess(base_rate_per_s=rate_per_s / 2.0,
                    burst_rate_per_s=3.0 * rate_per_s,
                    mean_off_ms=20_000.0, mean_on_ms=5_000.0),
        DiurnalPoissonProcess(base_rate_per_s=rate_per_s, amplitude=0.6,
                              phase_h=0.0, period_ms=duration_ms),
    ]


def _policy(gate: str) -> MinosPolicy:
    if gate == "off":
        return MinosPolicy(elysium_threshold=float("inf"), enabled=False)
    return MinosPolicy(elysium_threshold=THRESHOLD, max_retries=5)


def _platform(profile, gate: str, seed: int) -> FaaSPlatform:
    knobs = dataclasses.replace(profile.knobs(), max_instances=N_SERVERS)
    if gate == "fixed+admit":
        ctrl = QueueAwareAdmissionController(
            ClassicMinosController(_policy("fixed")),
            headroom=1.25, min_slots=2)
        return FaaSPlatform(SPEC, VM, None, seed=seed, profile=profile,
                            knobs=knobs, controller=ctrl)
    return FaaSPlatform(SPEC, VM, _policy(gate), seed=seed, profile=profile,
                        knobs=knobs)


def _run_cell(profile, process, gate: str, seeds, duration_ms: float):
    """Seed-pooled OpenLoopSummary for one (profile × process × gate)."""
    summaries = []
    for seed in seeds:
        plat = _platform(profile, gate, seed)
        run = run_open_loop(
            plat, process, rng=np.random.RandomState(7_000 + seed),
            duration_ms=duration_ms, drain_limit_ms=120_000.0)
        summaries.append(OpenLoopSummary.from_run(gate, plat, run))
    return summaries


def _pool(summaries, field) -> float:
    return float(np.mean([getattr(s, field) for s in summaries]))


def _vec_leg(smoke: bool, seeds, n_steps: int, rate_per_s: float):
    """The jitted open scan on the Poisson × {off, fixed} cells: wall
    clock per lane + the zero-recompile guard, mirroring grid_sweep."""
    max_retries = 3 if smoke else 5
    arms = stack_arms([
        arm_from_spec(SPEC, VM, profile=prof, gate=gate, threshold=THRESHOLD,
                      max_retries=max_retries, think_time_ms=0.0)
        for prof in _profiles() for gate in ("off", "fixed")
    ])
    proc = PoissonProcess(rate_per_s)
    iats = np.stack([proc.iats_ms(np.random.RandomState(9_000 + i), n_steps)
                     for i in seeds])
    max_attempts = max_retries + 1
    t0 = time.perf_counter()
    simulate_open_arms(arms, seeds=seeds, iats_ms=iats,
                       n_servers=N_SERVERS, max_attempts=max_attempts)
    t_first = time.perf_counter() - t0
    compiles = jit_stats["compiles"]
    t_cached = math.inf
    for _ in range(2):
        t0 = time.perf_counter()
        simulate_open_arms(arms, seeds=seeds, iats_ms=iats,
                           n_servers=N_SERVERS, max_attempts=max_attempts)
        t_cached = min(t_cached, time.perf_counter() - t0)
    recompiles = jit_stats["compiles"] - compiles
    lanes = 4 * len(list(seeds))
    return {
        "vec_lanes": lanes,
        "vec_n_steps": n_steps,
        "vec_wall_clock_s": round(t_cached, 4),
        "vec_compile_s": round(t_first - t_cached, 4),
        "vec_arrivals_per_sec": round(lanes * n_steps / t_cached, 1),
        "jit_recompiles_second_batch": recompiles,
    }


def openloop_sweep(quick: bool = False, *, smoke: bool = False,
                   report_timing: bool = True):
    """Returns (rows, headline, perf) — the benchmarks/run.py contract."""
    if smoke:
        profiles = _profiles()[:1]
        rates = (1.2,)
        seeds = range(2)
        duration_ms = 120_000.0
        gates = ("off", "fixed")
        vec_seeds, vec_steps = range(4), 150
    elif quick:
        profiles = _profiles()
        rates = (0.6, 1.2)
        seeds = range(2)
        duration_ms = 240_000.0
        gates = GATE_ARMS
        vec_seeds, vec_steps = range(8), 300
    else:
        profiles = _profiles()
        rates = (0.4, 0.8, 1.2, 1.6)
        seeds = range(3)
        duration_ms = 600_000.0
        gates = GATE_ARMS
        vec_seeds, vec_steps = range(16), 600

    t_sweep = time.perf_counter()
    rows = []
    cells = {}
    for prof in profiles:
        for rate in rates:
            for process in _processes(rate, duration_ms):
                for gate in gates:
                    summaries = _run_cell(prof, process, gate, seeds,
                                          duration_ms)
                    cells[(prof.name, rate, process.name, gate)] = summaries
                    rows.append({
                        "platform": prof.name,
                        "process": process.name,
                        "rate_per_s": rate,
                        "gate": gate,
                        "p50_ms": round(_pool(summaries, "p50_latency_ms"), 1),
                        "p95_ms": round(_pool(summaries, "p95_latency_ms"), 1),
                        "p99_ms": round(_pool(summaries, "p99_latency_ms"), 1),
                        "wait_p99_ms": round(_pool(summaries, "wait_p99_ms"), 1),
                        "drop_pct": round(100 * _pool(summaries, "drop_rate"), 2),
                        "defer_pct": round(100 * _pool(summaries, "defer_rate"), 2),
                        "cost_per_1k": round(_pool(summaries, "cost_per_1k"), 4),
                    })
    t_event = time.perf_counter() - t_sweep
    n_requests = sum(s.n_arrived for ss in cells.values() for s in ss)

    perf = _vec_leg(smoke, vec_seeds, vec_steps, rates[0])
    perf.update({
        "n_cells": len(cells),
        "n_requests": n_requests,
        "event_wall_clock_s": round(t_event, 3),
        "event_arrivals_per_sec": round(n_requests / t_event, 1),
    })

    # headline: burstiness cost at fixed mean load — the MMPP-vs-Poisson
    # P99 inflation on the first profile's top rate, fixed gate
    prof0, top = profiles[0].name, max(rates)
    gate0 = "fixed" if "fixed" in gates else gates[-1]
    p99_pois = _pool(cells[(prof0, top, "poisson", gate0)], "p99_latency_ms")
    p99_mmpp = _pool(cells[(prof0, top, "mmpp", gate0)], "p99_latency_ms")
    headline = (f"cells={len(cells)}_{prof0}_r{top:.1f}_{gate0}"
                f"_mmpp_p99_inflation={(p99_mmpp / p99_pois - 1) * 100:.0f}%")
    if report_timing:
        print(f"openloop_sweep timing: cells={len(cells)} "
              f"requests={n_requests} event={t_event:.2f}s "
              f"({n_requests / t_event:.0f} arrivals/s) "
              f"vec_cached={perf['vec_wall_clock_s']:.2f}s "
              f"({perf['vec_arrivals_per_sec']:.0f} arrivals/s) "
              f"recompiles={perf['jit_recompiles_second_batch']}",
              file=sys.stderr)
    return rows, headline, perf


def vec_admission_sweep(quick: bool = False, *, smoke: bool = False,
                        report_timing: bool = True):
    """Admission-pipeline arms through the jitted open scan (ISSUE 7): the
    in-scan defer (static admission bound) and drop (finite queue) paths
    as vectorized rate-ladder cells, summarized via
    :meth:`OpenLoopSummary.from_vec`.

    Three gate-fixed arms per profile: unbounded (the PR 6 scenario),
    ``+admit`` deferring at :func:`repro.core.control.static_admission_bound`
    over the N_SERVERS supply cap, and ``+drop`` shedding arrivals at a
    finite wait queue. Every rate reuses one compiled program (the iats
    batch shape is static); the event reference is the same scenario
    through :func:`run_open_loop`. Returns (rows, headline, perf)."""
    from repro.core.control import static_admission_bound

    if smoke:
        profiles = _profiles()[:1]
        rates = (0.9,)
        vec_seeds, n_steps = range(4), 200
        ev_arms = 2
    elif quick:
        profiles = _profiles()[:1]
        rates = (0.6, 0.9)
        vec_seeds, n_steps = range(8), 300
        ev_arms = 2
    else:
        profiles = _profiles()
        rates = (0.6, 0.9, 1.2)
        vec_seeds, n_steps = range(16), 400
        ev_arms = 3

    knobs = dataclasses.replace(_profiles()[0].knobs(),
                                max_instances=N_SERVERS)
    bound = static_admission_bound(knobs, headroom=1.25)
    arms, meta = [], []
    for prof in profiles:
        base = arm_from_spec(SPEC, VM, profile=prof, gate="fixed",
                             threshold=THRESHOLD, think_time_ms=0.0)
        for mode, arm in (
                ("fixed", base),
                ("fixed+admit", base._replace(admit_bound=bound)),
                ("fixed+drop", base._replace(
                    queue_capacity=float(2 * N_SERVERS)))):
            arms.append(arm)
            meta.append({"platform": prof.name, "mode": mode})
    stacked = stack_arms(arms)

    results, t_first, t_cached = {}, 0.0, math.inf
    compiles_before = jit_stats["compiles"]
    for rate in rates:
        proc = PoissonProcess(rate)
        iats = np.stack([
            proc.iats_ms(np.random.RandomState(11_000 + i), n_steps)
            for i in vec_seeds])
        t0 = time.perf_counter()
        results[rate] = simulate_open_arms(
            stacked, seeds=vec_seeds, iats_ms=iats, n_servers=N_SERVERS,
            collect_requests=True)
        dt = time.perf_counter() - t0
        if rate == rates[0]:
            t_first = dt
            compiles_after_first = jit_stats["compiles"]
            for _ in range(2):  # cached rerun of the first rate's batch
                t0 = time.perf_counter()
                simulate_open_arms(stacked, seeds=vec_seeds, iats_ms=iats,
                                   n_servers=N_SERVERS,
                                   collect_requests=True)
                t_cached = min(t_cached, time.perf_counter() - t0)
    recompiles = jit_stats["compiles"] - compiles_after_first
    assert jit_stats["compiles"] - compiles_before >= 1  # first batch compiled
    lanes = len(meta) * len(list(vec_seeds))

    # event reference: the same capped-supply scenario per arm
    best = math.inf
    prof0 = profiles[0]
    duration_ms = n_steps / rates[0] * 1e3
    for _ in range(2):
        t0 = time.perf_counter()
        for seed in range(ev_arms):
            plat = _platform(prof0, "fixed", seed)
            run_open_loop(plat, PoissonProcess(rates[0]),
                          rng=np.random.RandomState(13_000 + seed),
                          duration_ms=duration_ms, drain_limit_ms=120_000.0)
        best = min(best, (time.perf_counter() - t0) / ev_arms)
    ev_per_arm = best
    vec_per_lane = t_cached / lanes
    speedup = ev_per_arm / vec_per_lane

    rows = []
    for rate in rates:
        res = results[rate]
        for i, m in enumerate(meta):
            s = OpenLoopSummary.from_vec(m["mode"], res, arm=i)
            rows.append({
                "platform": m["platform"],
                "mode": m["mode"],
                "rate_per_s": rate,
                "p99_ms": round(s.p99_latency_ms, 1),
                "wait_p99_ms": round(s.wait_p99_ms, 1),
                "drop_pct": round(100 * s.drop_rate, 2),
                "defer_pct": round(100 * s.defer_rate, 2),
                "cost_per_1k": round(s.cost_per_1k, 4),
            })

    top = max(rates)
    by = {(r["platform"], r["mode"], r["rate_per_s"]): r for r in rows}
    plain = by[(profiles[0].name, "fixed", top)]["wait_p99_ms"]
    admit = by[(profiles[0].name, "fixed+admit", top)]["wait_p99_ms"]
    cut = (1.0 - admit / plain) * 100 if plain > 0 else 0.0
    headline = (f"cells={len(rows)}_{profiles[0].name}_r{top:.1f}"
                f"_admit_wait_p99_cut={cut:.0f}%")
    perf = {
        "n_cells": len(rows),
        "vec_lanes": lanes,
        "vec_n_steps": n_steps,
        "wall_clock_s": round(t_cached, 4),
        "compile_s": round(t_first - t_cached, 4),
        "events_per_sec": round(lanes * n_steps / t_cached, 1),
        "arms_per_sec": round(len(meta) / t_cached, 2),
        "event_engine_per_arm_s": round(ev_per_arm, 5),
        "speedup_per_arm": round(speedup, 1),
        "jit_recompiles_second_batch": recompiles,
        "admit_bound": bound,
    }
    if report_timing:
        print(f"vec_admission timing: cells={len(rows)} lanes={lanes} "
              f"steps={n_steps} first={t_first:.2f}s cached={t_cached:.2f}s "
              f"events/s={perf['events_per_sec']:.0f} "
              f"event_per_arm={ev_per_arm*1e3:.1f}ms "
              f"speedup={speedup:.0f}x recompiles={recompiles}",
              file=sys.stderr)
    return rows, headline, perf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 rates, shorter windows")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI cell set; asserts the vec zero-recompile "
                         "guard; deterministic stdout (timing on stderr)")
    ap.add_argument("--admission", action="store_true",
                    help="run the vec-admission (defer/drop in-scan) leg "
                         "instead of the event-engine rate ladder")
    args = ap.parse_args()
    sweep = vec_admission_sweep if args.admission else openloop_sweep
    name = "vec_admission_sweep" if args.admission else "openloop_sweep"
    rows, headline, perf = sweep(quick=args.quick, smoke=args.smoke)
    if args.smoke:
        assert perf["jit_recompiles_second_batch"] == 0, \
            f"second vec batch recompiled: {perf}"
        print(f"{name}_smoke_guards,jit_cache_hit=ok", file=sys.stderr)
    print(f"{name},{headline}")
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
