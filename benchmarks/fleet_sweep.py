"""Fleet meta-scheduler sweep: routing policies × rate ladder × drift
over a heterogeneous gen1+gen2+lambda fleet (EXPERIMENTS.md §Fleet
sweep; DESIGN.md §14).

Every fleet is a full Minos-gated :class:`~repro.sim.platform.FaaSPlatform`
with its own variability, cold-start profile, pricing tier, and supply
cap, all on one shared clock; one open-loop request stream is split
across them by a :class:`~repro.fleet.policies.RoutingPolicy`:

* **fleets** — gcf-gen1 (cheap, high σ, 1 req/instance), gcf-gen2
  (fast, stable, 4×-concurrent, expensive tier), aws-lambda (mid).
  Per-fleet ``max_instances`` caps are set so every *single* fleet
  saturates below the top aggregate rate — a static one-hot assignment
  must blow up there, which is exactly the regime a meta-scheduler
  exists for.
* **policies** — random (floor), the three static one-hots (the best of
  them is the bar the probabilistic split must beat), greedy (argmin
  expected response from live telemetry), probabilistic (periodically
  re-solved LP/waterfill split), and probabilistic+hedge (duplicate a
  straggler onto a second fleet after ``HEDGE_AFTER_MS``; the loser is
  still billed — honest accounting).
* **drift** — ``stable`` (low contention AR(1) ρ) vs ``drift`` (ρ=0.95
  reuse drift) legs; an Azure-Functions-style trace leg
  (tests/data/azure_invocations_sample.csv) replaces the Poisson stream
  in the non-smoke modes.

Timing goes to **stderr**; two ``--smoke`` runs produce byte-identical
stdout (the CI determinism diff). No vectorized leg: the router is
event-driven control flow (per-request callbacks), so there is no jitted
program to guard for recompiles here.

Usage: PYTHONPATH=src python benchmarks/fleet_sweep.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import os
import sys
import time

import numpy as np
from scipy import stats

from repro.core.policy import MinosPolicy
from repro.fleet import (
    FleetRouter,
    FleetSpec,
    GreedyRoutingPolicy,
    ProbabilisticRoutingPolicy,
    RandomRoutingPolicy,
    WeightedStaticRoutingPolicy,
    run_fleet_open_loop,
)
from repro.sim import (
    FunctionSpec,
    PlatformProfile,
    PoissonProcess,
    TraceProcess,
    VariationModel,
)
from repro.sim.metrics import FleetSummary

PASS_FRACTION = 0.4
BODY_MS = 1200.0
HEDGE_AFTER_MS = 4 * BODY_MS
AZURE_TRACE = os.path.join(os.path.dirname(__file__), "..", "tests",
                           "data", "azure_invocations_sample.csv")


def _spec(rho: float) -> FunctionSpec:
    return FunctionSpec(
        name="weather-linreg-fleet",
        prepare_ms=300.0,
        body_ms=BODY_MS,
        benchmark_ms=300.0,
        contention_rho=rho,
        benchmark_noise=0.08,
    )


def _threshold(vm: VariationModel, spec: FunctionSpec) -> float:
    """Per-fleet elysium threshold at the same pass fraction: each gate
    certifies the SAME share of its own speed distribution, so fleets
    differ in what a certified instance is worth, not in gate strictness."""
    sigma_tot = math.sqrt(vm.sigma ** 2 + spec.benchmark_noise ** 2)
    return spec.benchmark_ms * math.exp(
        stats.norm.ppf(PASS_FRACTION) * sigma_tot)


def _fleets(rho: float) -> list[FleetSpec]:
    """Heterogeneous ladder. Slots per fleet: gen1 4×1, gen2 1×4,
    lambda 3×1 — each alone saturates near ~2.5-3.3 req/s at BODY_MS,
    the combined supply comfortably absorbs the top ladder rate."""
    spec = _spec(rho)
    rows = [
        ("gen1", PlatformProfile.gcf_gen1(),
         VariationModel(sigma=0.30), 4),
        ("gen2", PlatformProfile.gcf_gen2(),
         VariationModel(sigma=0.10, day_factor=1.15), 1),
        ("lambda", PlatformProfile.aws_lambda(),
         VariationModel(sigma=0.20, day_factor=0.95), 3),
    ]
    fleets = []
    for name, prof, vm, cap in rows:
        knobs = dataclasses.replace(prof.knobs(), max_instances=cap)
        fleets.append(FleetSpec(
            name=name, spec=spec, variation=vm, profile=prof, knobs=knobs,
            policy=MinosPolicy(elysium_threshold=_threshold(vm, spec),
                               max_retries=5)))
    return fleets


def _policies(n_fleets: int, smoke: bool):
    """(arm label, policy factory, hedge_after_ms) triples. Factories,
    not instances: stateful policies must be rebuilt per run."""
    arms = [
        ("random", RandomRoutingPolicy, None),
        ("greedy", GreedyRoutingPolicy, None),
        ("probabilistic",
         lambda: ProbabilisticRoutingPolicy(prior_unit_ms=BODY_MS), None),
    ]
    for i in range(n_fleets):
        arms.insert(1 + i, (f"static[{i}]",
                            lambda i=i: WeightedStaticRoutingPolicy.one_hot(
                                i, n_fleets), None))
    if not smoke:
        arms.append(("prob+hedge",
                     lambda: ProbabilisticRoutingPolicy(
                         prior_unit_ms=BODY_MS), HEDGE_AFTER_MS))
    return arms


def _run_arm(fleets, label, policy_factory, hedge_ms, process, seeds,
             duration_ms):
    """Seed-pooled FleetSummary means for one (policy × process) cell."""
    summaries = []
    for seed in seeds:
        router = FleetRouter(fleets, policy_factory(), seed=seed,
                             hedge_after_ms=hedge_ms)
        run = run_fleet_open_loop(
            router, process, rng=np.random.RandomState(17_000 + seed),
            duration_ms=duration_ms, drain_limit_ms=180_000.0)
        router.check_conservation()  # every arm, not only under the env gate
        summaries.append(FleetSummary.from_run(label, router, run))
    return summaries


def _pool(summaries, field) -> float:
    return float(np.mean([getattr(s, field) for s in summaries]))


def _row(label, process_name, rate, drift, summaries):
    shares = np.mean(
        [[f["share"] for f in s.per_fleet] for s in summaries], axis=0)
    return {
        "policy": label,
        "process": process_name,
        "rate_per_s": rate,
        "drift": drift,
        "mean_ms": round(_pool(summaries, "mean_latency_ms"), 1),
        "p50_ms": round(_pool(summaries, "p50_latency_ms"), 1),
        "p95_ms": round(_pool(summaries, "p95_latency_ms"), 1),
        "p99_ms": round(_pool(summaries, "p99_latency_ms"), 1),
        "drop_pct": round(100 * _pool(summaries, "drop_rate"), 2),
        "cost_per_1k": round(_pool(summaries, "cost_per_1k"), 4),
        "hedges": int(round(_pool(summaries, "n_hedges"))),
        "hedge_waste": round(_pool(summaries, "hedge_waste_cost"), 4),
        "split": "/".join(f"{s:.2f}" for s in shares),
    }


def fleet_sweep(quick: bool = False, *, smoke: bool = False,
                report_timing: bool = True):
    """Returns (rows, headline, perf) — the benchmarks/run.py contract."""
    if smoke:
        rates = (2.0,)
        seeds = range(2)
        duration_ms = 60_000.0
        drifts = (("stable", 0.3),)
        azure = False
    elif quick:
        rates = (1.5, 3.0)
        seeds = range(2)
        duration_ms = 120_000.0
        drifts = (("stable", 0.3),)
        azure = True
    else:
        rates = (1.5, 3.0, 4.5)
        seeds = range(3)
        duration_ms = 180_000.0
        drifts = (("stable", 0.3), ("drift", 0.95))
        azure = True

    t_sweep = time.perf_counter()
    rows = []
    cells = {}
    n_fleets = len(_fleets(0.3))
    arms = _policies(n_fleets, smoke)
    for drift_label, rho in drifts:
        fleets = _fleets(rho)
        for rate in rates:
            process = PoissonProcess(rate)
            for label, factory, hedge_ms in arms:
                summaries = _run_arm(fleets, label, factory, hedge_ms,
                                     process, seeds, duration_ms)
                cells[(drift_label, rate, label)] = summaries
                rows.append(_row(label, process.name, rate, drift_label,
                                 summaries))
    if azure:
        # real-trace leg: replay the checked-in Azure-style IAT fixture
        # (deterministic arrivals; only routing and service draw RNG)
        process = TraceProcess.from_azure_csv(AZURE_TRACE, function="a7f3")
        fleets = _fleets(0.3)
        trace_rate = round(process.mean_rate_per_ms() * 1e3, 2)
        for label, factory, hedge_ms in (arms[0], arms[-2], arms[-1]):
            summaries = _run_arm(fleets, label, factory, hedge_ms, process,
                                 seeds, duration_ms)
            rows.append(_row(label, process.name, trace_rate, "stable",
                             summaries))
    t_event = time.perf_counter() - t_sweep
    n_requests = sum(s.n_arrived for ss in cells.values() for s in ss)

    # headline: the meta-scheduler claim at the top rate — probabilistic
    # split vs the best static single-fleet assignment
    top = max(rates)
    drift0 = drifts[0][0]
    statics = [(_pool(cells[(drift0, top, f"static[{i}]")],
                      "mean_latency_ms"), i) for i in range(n_fleets)]
    best_static_ms, best_i = min(statics)
    prob_ms = _pool(cells[(drift0, top, "probabilistic")], "mean_latency_ms")
    cut = (1.0 - prob_ms / best_static_ms) * 100 if best_static_ms else 0.0
    headline = (f"cells={len(rows)}_r{top:.1f}_prob_vs_static[{best_i}]"
                f"_mean_cut={cut:.0f}%")
    perf = {
        "n_cells": len(rows),
        "n_requests": n_requests,
        "event_wall_clock_s": round(t_event, 3),
        "event_arrivals_per_sec": round(n_requests / max(t_event, 1e-9), 1),
    }
    if report_timing:
        print(f"fleet_sweep timing: cells={len(rows)} "
              f"requests={n_requests} event={t_event:.2f}s "
              f"({perf['event_arrivals_per_sec']:.0f} arrivals/s)",
              file=sys.stderr)
    return rows, headline, perf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 rates, shorter windows, stable drift only")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI cell set; deterministic stdout "
                         "(timing on stderr)")
    args = ap.parse_args()
    rows, headline, _perf = fleet_sweep(quick=args.quick, smoke=args.smoke)
    if args.smoke:
        print("fleet_sweep_smoke_guards,conservation=ok", file=sys.stderr)
    print(f"fleet_sweep,{headline}")
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
