"""24 h diurnal sweep: the adaptive elysium threshold vs a fixed pre-tested
one under time-of-day platform variation (EXPERIMENTS.md §Diurnal sweep).

The Night Shift (Schirmer et al.; PAPERS.md) measures >10 % faster FaaS
execution at night. ``VariationModel.diurnal`` models that cycle; this sweep
quantifies what it does to the §III-A protocol: a threshold pre-tested at
one hour (the paper measured 3–4 pm UTC) is miscalibrated for the rest of
the day — too lax when the platform speeds up, too harsh when it slows —
while the §IV adaptive policy re-estimates the pass quantile from the live
probe stream and tracks the cycle. Rows are per simulated hour; the
headline reports each arm's analysis-time improvement over the ungated
baseline and the correlation between the adaptive threshold and the
(inverted) diurnal speed factor.

Usage: PYTHONPATH=src python benchmarks/diurnal_sweep.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.core.control import (
    ClassicMinosController,
    PassFractionController,
    ReprobeController,
)
from repro.core.elysium import pretest_threshold
from repro.core.policy import AdaptiveMinosPolicy, MinosPolicy
from repro.sim import (
    FaaSPlatform,
    FunctionSpec,
    PlatformProfile,
    VariationModel,
    improvement,
)
from repro.sim.experiment import PAPER_PRICING, PASS_FRACTION
from repro.sim.workload import run_closed_loop

# PAPER_SPEC shape, with the probe/body ratio kept and churn retained so
# cold-start probes keep flowing all day
SPEC = FunctionSpec(
    name="weather-linreg-diurnal",
    prepare_ms=1500.0,
    body_ms=1800.0,
    benchmark_ms=450.0,
    cold_start_ms=250.0,
    recycle_lifetime_ms=45_000.0,
    contention_rho=0.95,
    benchmark_noise=0.08,
)
DIURNAL_AMPLITUDE = 0.12   # Night Shift: >10 % day/night swing
PRETEST_HOUR = 15.0        # the paper's 3-4 pm UTC measurement slot
HOUR_MS = 3.6e6


class _RecordingAdaptive(AdaptiveMinosPolicy):
    """Adaptive policy that timestamps its threshold after every report
    (``clock`` is attached once the platform exists)."""

    def __init__(self, *args, **kw) -> None:
        super().__init__(*args, **kw)
        self.clock = None
        self.timeline: list[tuple[float, float]] = []

    def report(self, benchmark_result: float) -> None:
        super().report(benchmark_result)
        if self.clock is not None and self.warmed_up:
            self.timeline.append((self.clock.now, self.elysium_threshold))


def _pretest_at_hour(vm: VariationModel, hour: float, seed: int) -> float:
    """§III-A measured pre-test, run in a short window starting at ``hour``."""
    disabled = MinosPolicy(elysium_threshold=float("inf"), enabled=False)
    plat = FaaSPlatform(SPEC, vm, disabled, PAPER_PRICING, seed=seed)
    res = run_closed_loop(plat, n_vus=10, duration_ms=60_000.0,
                          start_ms=hour * HOUR_MS)
    speeds = [r.instance_speed for r in res if r.served_by_cold] or \
             [r.instance_speed for r in res]
    return pretest_threshold([SPEC.benchmark_ms / s for s in speeds], PASS_FRACTION)


def diurnal_sweep(quick: bool = False, *, hours: float | None = None,
                  n_vus: int | None = None, seed: int = 42):
    hours = hours if hours is not None else (8.0 if quick else 24.0)
    n_vus = n_vus if n_vus is not None else (6 if quick else 10)
    vm = VariationModel(sigma=0.15, diurnal_amplitude=DIURNAL_AMPLITUDE)

    fixed_thr = _pretest_at_hour(vm, PRETEST_HOUR, seed=seed * 7919)
    # The load-aware arms re-host the same function on concurrency-4
    # instances with a real self-contention curve (DESIGN.md §9 load
    # model); "adaptive-load" additionally judges probes at live pool
    # occupancy (gate_load_aware). Compared pairwise against its own
    # "disabled-load" baseline, not against the one-request-per-instance
    # arms above.
    loaded_profile = dataclasses.replace(
        PlatformProfile.gcf_gen2_loaded(), pricing=PAPER_PRICING,
        cold_start_ms=SPEC.cold_start_ms, recycle_lifetime_ms=SPEC.recycle_lifetime_ms,
    )
    arms: dict[str, tuple] = {
        "disabled": (MinosPolicy(elysium_threshold=float("inf"), enabled=False), None),
        "fixed": (MinosPolicy(elysium_threshold=fixed_thr, max_retries=5), None),
        "adaptive": (_RecordingAdaptive(PASS_FRACTION, max_retries=5), None),
        "disabled-load": (MinosPolicy(elysium_threshold=float("inf"), enabled=False),
                          loaded_profile),
        "adaptive-load": (AdaptiveMinosPolicy(PASS_FRACTION, max_retries=5),
                          loaded_profile),
    }

    per_arm_hour: dict[str, dict[int, list[float]]] = {}
    per_arm_mean: dict[str, float] = {}
    terminated: dict[str, int] = {}
    adaptive_timeline: list[tuple[float, float]] = []
    for arm, (policy, profile) in arms.items():
        plat = FaaSPlatform(SPEC, vm, policy, PAPER_PRICING, seed=seed,
                            profile=profile)
        if isinstance(policy, _RecordingAdaptive):
            policy.clock = plat.loop
        res = run_closed_loop(plat, n_vus=n_vus, duration_ms=hours * HOUR_MS)
        buckets: dict[int, list[float]] = {}
        for r in res:
            buckets.setdefault(int(r.t_completed_ms // HOUR_MS), []).append(r.analysis_ms)
        per_arm_hour[arm] = buckets
        per_arm_mean[arm] = float(np.mean([r.analysis_ms for r in res]))
        terminated[arm] = plat.instances_terminated
        if isinstance(policy, _RecordingAdaptive):
            adaptive_timeline = policy.timeline

    thr_by_hour: dict[int, list[float]] = {}
    for t, thr in adaptive_timeline:
        thr_by_hour.setdefault(int(t // HOUR_MS), []).append(thr)

    rows = []
    for h in sorted(per_arm_hour["disabled"]):
        thr_h = float(np.mean(thr_by_hour[h])) if h in thr_by_hour else float("nan")
        rows.append({
            "hour": h,
            "diurnal_factor": round(vm.diurnal((h + 0.5) * HOUR_MS), 4),
            "disabled_ms": round(float(np.mean(per_arm_hour["disabled"][h])), 1),
            "fixed_ms": round(float(np.mean(per_arm_hour["fixed"].get(h, [np.nan]))), 1),
            "adaptive_ms": round(float(np.mean(per_arm_hour["adaptive"].get(h, [np.nan]))), 1),
            "disabled_load_ms": round(float(np.mean(per_arm_hour["disabled-load"].get(h, [np.nan]))), 1),
            "adaptive_load_ms": round(float(np.mean(per_arm_hour["adaptive-load"].get(h, [np.nan]))), 1),
            "adaptive_thr_ms": round(thr_h, 1),
            "fixed_thr_ms": round(fixed_thr, 1),
        })

    # does the adaptive threshold track the cycle? threshold ∝ 1/diurnal in
    # log space, so corr(log thr, -log diurnal) → +1 under perfect tracking
    tracked = [(np.log(r["adaptive_thr_ms"]), -np.log(r["diurnal_factor"]))
               for r in rows if np.isfinite(r["adaptive_thr_ms"])]
    if len(tracked) >= 3:
        a, d = np.array(tracked).T
        tracking_corr = float(np.corrcoef(a, d)[0, 1])
    else:
        tracking_corr = float("nan")

    imp_fixed = improvement(per_arm_mean["disabled"], per_arm_mean["fixed"])
    imp_adaptive = improvement(per_arm_mean["disabled"], per_arm_mean["adaptive"])
    # load arms compare pairwise: same (loaded) hosting, gate on vs off
    imp_load = improvement(per_arm_mean["disabled-load"],
                           per_arm_mean["adaptive-load"])
    headline = (
        f"fixed_improvement={imp_fixed*100:.1f}%"
        f"_adaptive_improvement={imp_adaptive*100:.1f}%"
        f"_adaptive_advantage={(imp_adaptive-imp_fixed)*100:.1f}pp"
        f"_tracking_corr={tracking_corr:.2f}"
        f"_load_aware_improvement={imp_load*100:.1f}%"
    )
    return rows, headline


def controller_sweep(quick: bool = False, *, hours: float | None = None,
                     n_vus: int | None = None, seed: int = 42):
    """The ``--controllers`` arm (EXPERIMENTS.md §Controller sweep): the two
    drift-facing control-plane controllers against the static baseline they
    generalize, on the diurnal drift scenario. One row per arm:

    * ``disabled`` — no gate (the improvement denominator);
    * ``adaptive`` — §IV online threshold at the STATIC pass fraction 0.4
      (the pre-control-plane best; both controllers must beat it);
    * ``passfrac`` — :class:`~repro.core.control.PassFractionController`:
      pass fraction re-solved online from live Welford reuse/probe/body
      estimates (ROADMAP: adaptive pass fraction);
    * ``reprobe`` — :class:`~repro.core.control.ReprobeController` around
      the classic adaptive stack: warm re-benchmark every drift half-life
      (ROADMAP: re-probing under drift).

    Each row carries the per-decision-point handler summary, so the
    one-command harness shows exactly which controller answered what.
    Fully deterministic per seed — CI runs the smoke config twice and
    diffs the outputs (the control plane must not introduce any
    unseeded state).
    """
    hours = hours if hours is not None else (8.0 if quick else 24.0)
    n_vus = n_vus if n_vus is not None else (6 if quick else 10)
    vm = VariationModel(sigma=0.15, diurnal_amplitude=DIURNAL_AMPLITUDE)
    half_life = ReprobeController.half_life_uses(SPEC.contention_rho)

    def arms():
        yield "disabled", MinosPolicy(elysium_threshold=float("inf"),
                                      enabled=False), None
        yield "adaptive", AdaptiveMinosPolicy(PASS_FRACTION, max_retries=5), None
        yield "passfrac", None, PassFractionController(PASS_FRACTION,
                                                       max_retries=5)
        yield "reprobe", None, ReprobeController(
            ClassicMinosController(AdaptiveMinosPolicy(PASS_FRACTION,
                                                       max_retries=5)),
            max_uses_since_probe=half_life,
        )

    rows = []
    mean_ms: dict[str, float] = {}
    for arm, policy, controller in arms():
        plat = FaaSPlatform(SPEC, vm, policy, PAPER_PRICING, seed=seed,
                            controller=controller)
        res = run_closed_loop(plat, n_vus=n_vus, duration_ms=hours * HOUR_MS)
        mean_ms[arm] = float(np.mean([r.analysis_ms for r in res]))
        ctrl = plat.controller
        pf = getattr(ctrl, "pass_fraction", None)
        rows.append({
            "arm": arm,
            "requests": len(res),
            "mean_analysis_ms": round(mean_ms[arm], 1),
            "improvement_pct": 0.0,  # filled once 'disabled' is known
            "cost_per_m_req": round(
                plat.cost.total / max(1, len(res)) * 1e6, 2),
            "terminated": plat.instances_terminated,
            "retired": plat.instances_retired,
            "reprobes": plat.reprobes,
            "final_pass_fraction": round(pf, 3) if pf is not None else "",
            "decisions": ctrl.decision_summary(),
        })
    for r in rows:
        r["improvement_pct"] = round(
            improvement(mean_ms["disabled"], mean_ms[r["arm"]]) * 100, 2)

    imp = {r["arm"]: r["improvement_pct"] for r in rows}
    headline = (
        f"adaptive={imp['adaptive']:.1f}%_passfrac={imp['passfrac']:.1f}%"
        f"_reprobe={imp['reprobe']:.1f}%"
        f"_passfrac_adv={imp['passfrac'] - imp['adaptive']:.1f}pp"
        f"_reprobe_adv={imp['reprobe'] - imp['adaptive']:.1f}pp"
    )
    return rows, headline


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="8 h window, 6 VUs")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: 2 h window, 4 VUs")
    ap.add_argument("--controllers", action="store_true",
                    help="control-plane arms: passfrac + reprobe vs the "
                         "static-fraction adaptive baseline")
    args = ap.parse_args()
    if args.controllers:
        kw = dict(quick=True, hours=2.0, n_vus=4) if args.smoke else \
            dict(quick=args.quick)
        rows, headline = controller_sweep(**kw)
        print(f"diurnal_controller_sweep,{headline}")
    elif args.smoke:
        rows, headline = diurnal_sweep(quick=True, hours=2.0, n_vus=4)
        print(f"diurnal_sweep,{headline}")
    else:
        rows, headline = diurnal_sweep(quick=args.quick)
        print(f"diurnal_sweep,{headline}")
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
